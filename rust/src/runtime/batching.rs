//! Cross-request batching: execute several queued requests as ONE walk of
//! the generated flow, stacked along a shared leading dynamic symbol.
//!
//! Bucketed kernels make the leading dimension cheap: a kernel compiled
//! for bucket extents serves any actual extent inside the bucket, so three
//! queued requests of 2 rows each can ride one launch at 6 rows — landing
//! in the same bucket family (often the very same kernel) that solo
//! requests already populated. The serving coordinator groups queued
//! requests whose *residual* symbol bindings (everything except the
//! leading batch symbol) agree and hands them to
//! [`Executor::run_batch`](crate::runtime::executor::Executor), which
//! concatenates their inputs along the leading axis, executes the step
//! sequence once, and slices per-request outputs back out.
//!
//! Batching must stay **bit-exact** against the single-request
//! interpreter, and most interesting programs (transformer, BERT) are not
//! uniformly row-parallel: attention mixes rows across the dynamic axis,
//! so naively concatenating sequences would attend across requests. The
//! static [`analyze`] pass therefore classifies every step of the
//! generated flow:
//!
//! * [`BatchMode::Stacked`] — the step maps rows of the leading symbol
//!   independently (elementwise chains, row-wise reduces such as
//!   softmax/layernorm over trailing axes, `[rows, k] · [k, n]` GEMMs,
//!   embedding gathers). Executed once over the concatenated values; row
//!   `r` of the stacked result is bitwise the row the owning request
//!   would have computed alone, because bucketed kernels compute each
//!   row from that row's lanes only (trailing-axis masking is shared —
//!   the residual bindings agree by construction).
//! * [`BatchMode::Shared`] — derived from constants only; executed once
//!   and shared by every member.
//! * [`BatchMode::PerRequest`] — anything that couples rows across the
//!   leading axis (attention scores/softmax over the dynamic axis,
//!   axis-0 transposes/slices, extent reads). Executed once per member
//!   request, exactly as solo execution would.
//!
//! Values cross between the groups by slicing (stacked → per-request
//! rows) and concatenation (per-request → stacked), both contiguous
//! row-range copies accounted in `RunMetrics::batch_stack_bytes`.
//!
//! Programs with data-dependent extents (`Unique`) or shape math that
//! reads tensor contents (`ShapeExpr::Elem`) are ineligible and fall back
//! to solo execution, as does any batch whose residual bindings disagree.
//! See docs/runtime.md §Cross-request batching.
//!
//! Batched dispatches run the same **three tiers** as solo requests:
//! *interpret* (first sight of a group shape: per-step symbol resolution
//! and cache hashing over the stacked walk), *record* (interpret plus a
//! [`BatchPlanRecorder`] capturing the walk as a
//! [`BatchPlan`](crate::runtime::plan::BatchPlan), keyed by residual
//! bindings + sorted member extents), and *replay* (repeat same-shape
//! groups skip resolution, hashing, and the per-step mode branching, and
//! chain Stacked/Shared fused-kernel/GEMM results dev→dev through
//! persistent device buffers — only member crossings, host ops, and
//! program outputs read back). The per-program analysis itself is computed
//! once at compile time and threaded through `Executor::batch_info`, so no
//! dispatch ever re-derives the classification.

use crate::codegen::cache::CompiledKernel;
use crate::dhlo::{DType, Module, Op, ValueId};
use crate::library::{GemmKey, GemmSrc, WeightKey};
use crate::program::{Program, Step};
use crate::runtime::executor::{crop_box, pad_box, weight_ref_of, DevSlot, ExecOutput, Executor};
use crate::runtime::metrics::RunMetrics;
use crate::runtime::pjrt::{Device, DeviceTensor};
use crate::runtime::plan::{
    binding_vector, host_guards_hold, BatchPlan, BatchPlanKey, BatchPlanRecorder,
    BatchPlannedStep, PlanWeight, PlannedStep,
};
use crate::runtime::reference::eval_op;
use crate::runtime::shape_env::{NoVals, SymEnv};
use crate::runtime::tensor::{Data, Tensor};
use crate::shape::{Dim, ShapeExpr, SymId};
use anyhow::{Context, Result};
use std::collections::{HashMap, HashSet};
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

/// How one step of the generated flow executes inside a batched dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchMode {
    /// Executed once over values stacked along the leading batch symbol.
    Stacked,
    /// Derived from constants only: executed once, shared by all members.
    Shared,
    /// Executed once per member request (the solo semantics).
    PerRequest,
}

/// Result of the static batchability analysis of one program.
#[derive(Debug)]
pub struct BatchAnalysis {
    /// The canonical leading symbol requests stack along; `None` means the
    /// program is ineligible (see `reason`) and batches run solo.
    pub batch_sym: Option<SymId>,
    /// Why the program is ineligible (diagnostic; `None` when eligible).
    pub reason: Option<&'static str>,
    /// Execution mode per `Program::steps` entry (empty when ineligible).
    pub step_modes: Vec<BatchMode>,
    /// Mode of each IR value's materialized form (indexed by `ValueId`).
    pub value_modes: Vec<BatchMode>,
    /// Number of launch-carrying steps that run stacked (the win).
    pub stacked_steps: usize,
}

impl BatchAnalysis {
    pub fn eligible(&self) -> bool {
        self.batch_sym.is_some()
    }

    fn ineligible(reason: &'static str) -> BatchAnalysis {
        BatchAnalysis {
            batch_sym: None,
            reason: Some(reason),
            step_modes: Vec::new(),
            value_modes: Vec::new(),
            stacked_steps: 0,
        }
    }
}

/// Grouping key for batch assembly: the binding vector *minus* the leading
/// batch symbol. Requests may differ in their leading extent (that is the
/// axis batches stack along) but must agree on every other dynamic dim,
/// because stacked launches share one set of trailing extent scalars.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub residual: Vec<(SymId, i64)>,
}

/// Compute the grouping key AND leading extent of a request, or `None`
/// when the program is ineligible or the inputs do not bind (such requests
/// serve solo and surface their errors through the normal run path). The
/// extent lets the coordinator steer assembly toward group shapes that
/// already have a recorded batch plan.
pub fn group_key_extent(
    m: &Module,
    analysis: &BatchAnalysis,
    inputs: &[Tensor],
) -> Option<(BatchKey, i64)> {
    let b = analysis.batch_sym?;
    let mut env = SymEnv::new();
    env.bind_params(m, inputs).ok()?;
    let ext = *env.resolved().get(&b)?;
    let mut residual = binding_vector(&env);
    residual.retain(|&(s, _)| s != b);
    Some((BatchKey { residual }, ext))
}

/// The grouping key alone (see [`group_key_extent`]).
pub fn group_key(m: &Module, analysis: &BatchAnalysis, inputs: &[Tensor]) -> Option<BatchKey> {
    group_key_extent(m, analysis, inputs).map(|(k, _)| k)
}

/// The bound shape of one dispatch group: member environments, leading
/// extents (arrival order), stacked row offsets, and the shared residual
/// binding. Deriving it is the **cheap per-group binding check** the plan
/// tiers run instead of any per-step work: bind each member's parameters,
/// split off the leading extent, verify the residuals agree.
pub struct GroupShape {
    pub envs: Vec<SymEnv>,
    pub extents: Vec<i64>,
    pub offsets: Vec<usize>,
    pub residual: Vec<(SymId, i64)>,
}

impl GroupShape {
    /// The batch-plan cache key of this group (extents sorted: the stacked
    /// walk is order-independent, see `runtime::plan::BatchPlanKey`).
    /// `epoch` is the live bucket-policy epoch — walks recorded under an
    /// older bucket family become unreachable after a boundary swap.
    pub fn plan_key(&self, program: u64, epoch: u64) -> BatchPlanKey {
        let mut extents = self.extents.clone();
        extents.sort_unstable();
        BatchPlanKey { program, residual: self.residual.clone(), extents, epoch }
    }
}

/// Bind every member of a prospective group and check it can stack.
/// Returns `None` when any member fails to bind or the residual bindings
/// disagree — the caller then serves the members solo (binding errors
/// surface through the normal solo run path).
pub fn group_shape(
    m: &Module,
    analysis: &BatchAnalysis,
    requests: &[Vec<Tensor>],
) -> Option<GroupShape> {
    let b_sym = analysis.batch_sym?;
    let k = requests.len();
    let mut envs = Vec::with_capacity(k);
    let mut extents = Vec::with_capacity(k);
    let mut offsets = Vec::with_capacity(k + 1);
    let mut residual0: Option<Vec<(SymId, i64)>> = None;
    offsets.push(0usize);
    for (i, r) in requests.iter().enumerate() {
        let mut e = SymEnv::new();
        if e.bind_params(m, r).is_err() {
            return None;
        }
        let Some(&ext) = e.resolved().get(&b_sym) else {
            return None;
        };
        let mut residual = binding_vector(&e);
        residual.retain(|&(s, _)| s != b_sym);
        match &residual0 {
            None => residual0 = Some(residual),
            Some(first) if first != &residual => return None,
            Some(_) => {}
        }
        offsets.push(offsets[i] + ext as usize);
        extents.push(ext);
        envs.push(e);
    }
    Some(GroupShape { envs, extents, offsets, residual: residual0.unwrap_or_default() })
}

/// Dims classification relative to the batch symbol.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TyClass {
    /// No batch-tied symbol anywhere: identical across requests at fixed
    /// residual bindings.
    Free,
    /// Exactly the batch symbol, at axis 0 only: stackable by row concat.
    Lead,
    /// A batch-tied symbol somewhere else (or derived): never stackable.
    Tangled,
}

fn classify_dims(m: &Module, dims: &[Dim], b: SymId, tied: &HashSet<SymId>) -> TyClass {
    let mut lead = false;
    for (i, d) in dims.iter().enumerate() {
        if let Dim::Sym(s) = m.syms.canon_dim(*d) {
            if tied.contains(&s) {
                if i == 0 && s == b {
                    lead = true;
                } else {
                    return TyClass::Tangled;
                }
            }
        }
    }
    if lead {
        TyClass::Lead
    } else {
        TyClass::Free
    }
}

/// Does this shape expression read tensor contents (`Elem`) or
/// data-dependent extents (`DataDep`)? Either makes batched shape
/// resolution unsound (the stacked tensor's contents are not any single
/// request's), so such programs are ineligible.
fn expr_reads_values(e: &ShapeExpr) -> bool {
    let mut deps = Vec::new();
    e.value_deps(&mut deps);
    !deps.is_empty()
}

/// Is this expression's value coupled to the leading extent? `InputDim`
/// of axis 0 reads the (batched) leading extent directly; symbol
/// references couple through the tied set.
fn expr_tied(m: &Module, e: &ShapeExpr, tied: &HashSet<SymId>) -> bool {
    match e {
        ShapeExpr::InputDim { axis, .. } => *axis == 0,
        ShapeExpr::Dim(Dim::Sym(s)) => tied.contains(&m.syms.canon(*s)),
        ShapeExpr::Dim(Dim::Fixed(_)) | ShapeExpr::Const(_) => false,
        ShapeExpr::Elem { .. } | ShapeExpr::DataDep { .. } => false,
        ShapeExpr::Add(a, b2)
        | ShapeExpr::Sub(a, b2)
        | ShapeExpr::Mul(a, b2)
        | ShapeExpr::CeilDiv(a, b2)
        | ShapeExpr::Max(a, b2) => expr_tied(m, a, tied) || expr_tied(m, b2, tied),
    }
}

/// Does the op map axis 0 independently, given its operand placement?
/// `op_tys[i]` is the mode+class of operand `i` as materialized for the
/// stacked launch. Only called once the output is `Lead` and operands are
/// individually stackable.
fn op_maps_rows(
    m: &Module,
    op: &Op,
    operands: &[ValueId],
    op_tys: &[(BatchMode, TyClass)],
) -> bool {
    match op {
        Op::Un(_) | Op::Bin(_) | Op::Cmp(_) | Op::Select | Op::Convert(_) => true,
        // Broadcast maps operand axis i to output axis dims[i]: a stacked
        // operand must keep its rows on axis 0; a shared operand must not
        // be spread along axis 0 (that would index values by row position,
        // which differs between the stacked and solo layouts).
        Op::Broadcast { dims } => match op_tys[0].1 {
            TyClass::Lead => dims.first() == Some(&0),
            TyClass::Free => !dims.contains(&0),
            TyClass::Tangled => false,
        },
        Op::Transpose { perm } => perm.first() == Some(&0),
        // Row-preserving metadata reshape: both sides carry the batch at
        // axis 0, so per-row element counts match and rows stay intact.
        Op::Reshape => true,
        Op::Reduce { axes, .. } => !axes.contains(&0),
        Op::Concat { axis } => *axis != 0,
        // Embedding lookup: shared table, stacked indices — each output
        // row depends on one index row only.
        Op::Gather { .. } => {
            op_tys[0].1 == TyClass::Free
                && op_tys[0].0 == BatchMode::Shared
                && op_tys[1].1 == TyClass::Lead
        }
        // `[rows, k] · [k, n]` with a shared RHS is row-parallel;
        // `[b, m, k] · [b, k, n]` with both sides stacked along the batch
        // axis is slice-parallel.
        Op::Dot => {
            let lhs_rank = m.instrs[operands[0]].ty.dims.len();
            match op_tys[1].0 {
                BatchMode::Shared => lhs_rank == 2 && op_tys[1].1 == TyClass::Free,
                _ => lhs_rank == 3 && op_tys[1].1 == TyClass::Lead,
            }
        }
        // Slices/pads/dynamic twins/iota/dim reads either address rows by
        // absolute position or read extents: per-request only.
        _ => false,
    }
}

/// Classify one value-defining step outside fusion groups.
fn classify_value_step(
    m: &Module,
    v: ValueId,
    modes: &[BatchMode],
    b: SymId,
    tied: &HashSet<SymId>,
) -> BatchMode {
    let ins = &m.instrs[v];
    let out = classify_dims(m, &ins.ty.dims, b, tied);
    let op_tys: Vec<(BatchMode, TyClass)> = ins
        .operands
        .iter()
        .map(|&o| (modes[o], classify_dims(m, &m.instrs[o].ty.dims, b, tied)))
        .collect();
    if out == TyClass::Free && op_tys.iter().all(|&(mo, _)| mo == BatchMode::Shared) {
        return BatchMode::Shared;
    }
    // A stacked launch can consume shared (request-independent) values and
    // anything with the batch cleanly at axis 0 — per-request values with a
    // Lead type are concatenated on demand.
    let operands_ok = op_tys.iter().all(|&(mo, tc)| match mo {
        BatchMode::Shared => tc == TyClass::Free,
        BatchMode::Stacked | BatchMode::PerRequest => tc == TyClass::Lead,
    });
    if out == TyClass::Lead && operands_ok && op_maps_rows(m, &ins.op, &ins.operands, &op_tys) {
        BatchMode::Stacked
    } else {
        BatchMode::PerRequest
    }
}

/// Classify a fused-kernel launch: every member must map rows
/// independently for the group to run stacked.
fn classify_group(
    m: &Module,
    fl: &crate::program::FusedLaunch,
    modes: &[BatchMode],
    b: SymId,
    tied: &HashSet<SymId>,
) -> BatchMode {
    let root = classify_dims(m, &m.ty(fl.root).dims, b, tied);
    let in_tys: Vec<(BatchMode, TyClass)> = fl
        .inputs
        .iter()
        .map(|&v| (modes[v], classify_dims(m, &m.instrs[v].ty.dims, b, tied)))
        .collect();
    if root == TyClass::Free && in_tys.iter().all(|&(mo, _)| mo == BatchMode::Shared) {
        return BatchMode::Shared;
    }
    let inputs_ok = in_tys.iter().all(|&(mo, tc)| match mo {
        BatchMode::Shared => tc == TyClass::Free,
        BatchMode::Stacked | BatchMode::PerRequest => tc == TyClass::Lead,
    });
    if root != TyClass::Lead || !inputs_ok {
        return BatchMode::PerRequest;
    }
    // Interior members: type-driven (classes exist only for externals).
    for &mv in &fl.group.members {
        let ins = &m.instrs[mv];
        let out_c = classify_dims(m, &ins.ty.dims, b, tied);
        let op_cs: Vec<TyClass> = ins
            .operands
            .iter()
            .map(|&o| classify_dims(m, &m.instrs[o].ty.dims, b, tied))
            .collect();
        if out_c == TyClass::Tangled || op_cs.contains(&TyClass::Tangled) {
            return BatchMode::PerRequest;
        }
        if out_c == TyClass::Free {
            if op_cs.contains(&TyClass::Lead) {
                // Dropping the batch axis inside the kernel couples rows.
                return BatchMode::PerRequest;
            }
            continue;
        }
        let ok = match &ins.op {
            Op::Un(_) | Op::Bin(_) | Op::Cmp(_) | Op::Select | Op::Convert(_) => true,
            Op::Broadcast { dims } => match op_cs[0] {
                TyClass::Lead => dims.first() == Some(&0),
                TyClass::Free => !dims.contains(&0),
                TyClass::Tangled => false,
            },
            Op::Transpose { perm } => perm.first() == Some(&0),
            Op::Reduce { axes, .. } => !axes.contains(&0),
            // Externals (params) appearing as members keep their rows.
            Op::Param { .. } => true,
            _ => false,
        };
        if !ok {
            return BatchMode::PerRequest;
        }
    }
    BatchMode::Stacked
}

/// Statically analyze a program for cross-request batchability. Pure
/// shape/dataflow reasoning — no inputs involved — so the result is
/// computed once per program and cached by the executor.
pub fn analyze(prog: &Program) -> BatchAnalysis {
    let m = &prog.module;

    // The leading batch symbol: every entry parameter must carry it at
    // axis 0 (otherwise a parameter would have to be bit-identical across
    // batch members, which the coordinator cannot know).
    let b = match m.params.first().and_then(|ty| ty.dims.first()) {
        Some(&d) => match m.syms.canon_dim(d) {
            Dim::Sym(s) => s,
            Dim::Fixed(_) => {
                return BatchAnalysis::ineligible("first parameter has a static leading dim")
            }
        },
        None => return BatchAnalysis::ineligible("program has no parameters to stack"),
    };
    for ty in &m.params {
        match ty.dims.first().map(|&d| m.syms.canon_dim(d)) {
            Some(Dim::Sym(s)) if s == b => {}
            _ => {
                return BatchAnalysis::ineligible(
                    "parameters do not share one leading dynamic symbol",
                )
            }
        }
    }
    if m.instrs.iter().any(|i| matches!(i.op, Op::Unique)) {
        return BatchAnalysis::ineligible("data-dependent extents (unique)");
    }

    // Symbols actually used by instruction types, transitively through
    // their definitions (only canonical representatives resolve at
    // runtime). Reject content-dependent shape math outright.
    let mut used: HashSet<SymId> = HashSet::new();
    let mut stack: Vec<SymId> = Vec::new();
    for ins in &m.instrs {
        for &d in &ins.ty.dims {
            if let Dim::Sym(s) = m.syms.canon_dim(d) {
                stack.push(s);
            }
        }
    }
    while let Some(s) = stack.pop() {
        if !used.insert(s) {
            continue;
        }
        let mut deps = Vec::new();
        m.syms.def(s).deps(&mut deps);
        for d in deps {
            stack.push(m.syms.canon(d));
        }
    }
    for &s in &used {
        if expr_reads_values(m.syms.def(s)) {
            return BatchAnalysis::ineligible("shape math reads tensor contents");
        }
    }

    // Symbols whose value is coupled to the leading extent (the batch
    // symbol itself, anything derived from it, anything reading a
    // parameter's axis-0 extent).
    let mut tied: HashSet<SymId> = HashSet::new();
    tied.insert(b);
    loop {
        let mut changed = false;
        for &s in &used {
            if !tied.contains(&s) && expr_tied(m, m.syms.def(s), &tied) {
                tied.insert(s);
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    for ty in &m.params {
        if classify_dims(m, &ty.dims, b, &tied) != TyClass::Lead {
            return BatchAnalysis::ineligible("parameter entangled beyond its leading dim");
        }
    }

    // Dataflow pass over the step sequence.
    let n = m.instrs.len();
    let mut value_modes = vec![BatchMode::PerRequest; n];
    for (id, ins) in m.instrs.iter().enumerate() {
        match ins.op {
            Op::Const { .. } => value_modes[id] = BatchMode::Shared,
            Op::Param { .. } => value_modes[id] = BatchMode::Stacked,
            _ => {}
        }
    }
    let mut step_modes = Vec::with_capacity(prog.steps.len());
    let mut stacked_steps = 0usize;
    for step in &prog.steps {
        let mode = match step {
            Step::Dealloc { .. } => BatchMode::Shared,
            Step::EvalHost { value }
            | Step::Bitcast { value }
            | Step::LaunchOp { value }
            | Step::LibraryCall { value } => {
                let mo = classify_value_step(m, *value, &value_modes, b, &tied);
                value_modes[*value] = mo;
                mo
            }
            Step::LaunchFused { idx } => {
                let fl = &prog.fused[*idx];
                let mo = classify_group(m, fl, &value_modes, b, &tied);
                value_modes[fl.root] = mo;
                mo
            }
        };
        if mode == BatchMode::Stacked
            && matches!(
                step,
                Step::LaunchFused { .. } | Step::LaunchOp { .. } | Step::LibraryCall { .. }
            )
        {
            stacked_steps += 1;
        }
        step_modes.push(mode);
    }
    if stacked_steps == 0 {
        return BatchAnalysis::ineligible("no leading-parallel launches to batch");
    }

    BatchAnalysis {
        batch_sym: Some(b),
        reason: None,
        step_modes,
        value_modes,
        stacked_steps,
    }
}

/// Per-request results of one batched dispatch.
pub struct BatchOutput {
    /// `outputs[i]` holds request `i`'s program outputs, bit-identical to
    /// what a solo run of that request would produce.
    pub outputs: Vec<Vec<Tensor>>,
    /// Aggregate metrics of the whole dispatch (launch counts cover the
    /// batch once, which is the point).
    pub metrics: RunMetrics,
}

/// Materialize the stacked (or shared) form of a value: either already in
/// the joint store, or assembled by concatenating the per-request parts.
fn joint_value(
    joint: &mut [Option<Rc<Tensor>>],
    per: &[Option<Vec<Rc<Tensor>>>],
    metrics: &mut RunMetrics,
    v: ValueId,
) -> Result<Rc<Tensor>> {
    if let Some(t) = &joint[v] {
        return Ok(t.clone());
    }
    let parts = per[v]
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("value %{v} has no live batched form"))?;
    let refs: Vec<&Tensor> = parts.iter().map(|r| r.as_ref()).collect();
    let t = Tensor::concat0(&refs).with_context(|| format!("stacking value %{v}"))?;
    metrics.batch_stack_bytes += t.byte_size() as u64;
    let rc = Rc::new(t);
    joint[v] = Some(rc.clone());
    Ok(rc)
}

/// Materialize request `i`'s view of a value: the per-request slot, the
/// shared tensor, or a row slice of the stacked form.
fn per_value(
    joint: &[Option<Rc<Tensor>>],
    per: &mut [Option<Vec<Rc<Tensor>>>],
    analysis: &BatchAnalysis,
    offsets: &[usize],
    metrics: &mut RunMetrics,
    v: ValueId,
    i: usize,
) -> Result<Rc<Tensor>> {
    if let Some(parts) = &per[v] {
        return Ok(parts[i].clone());
    }
    let t = joint[v]
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("value %{v} has no live batched form"))?;
    if analysis.value_modes[v] == BatchMode::Shared {
        return Ok(t.clone());
    }
    // Slice every member at once (contiguous leading-axis ranges).
    let k = offsets.len() - 1;
    let mut parts = Vec::with_capacity(k);
    for j in 0..k {
        let rows = offsets[j + 1] - offsets[j];
        let s = t
            .slice0(offsets[j], rows)
            .with_context(|| format!("splitting value %{v} for request {j}"))?;
        metrics.batch_stack_bytes += s.byte_size() as u64;
        parts.push(Rc::new(s));
    }
    let out = parts[i].clone();
    per[v] = Some(parts);
    Ok(out)
}

impl Executor {
    /// The (cached) batchability analysis of a program. Normally seeded at
    /// compile time by `DiscCompiler` (see `Executor::seed_batch_analysis`)
    /// and shared across forked workers; computing it here is the cold
    /// fallback for standalone executors, counted in
    /// `Executor::batch_analyses` so tests can assert dispatches never
    /// re-derive the classification.
    pub fn batch_analysis(&mut self, prog: &Program) -> Arc<BatchAnalysis> {
        if let Some(a) = self.batch_info.get(&prog.id) {
            return a.clone();
        }
        self.batch_analyses += 1;
        let a = Arc::new(analyze(prog));
        self.batch_info.insert(prog.id, a.clone());
        a
    }

    /// Execute several requests as one batched dispatch (see the module
    /// docs). Outputs are bit-identical to solo runs. Falls back to
    /// sequential solo execution for singletons, ineligible programs, and
    /// batches whose residual bindings disagree (requests that cannot even
    /// bind fall back too, so their errors surface through the normal solo
    /// run path).
    pub fn run_batch(&mut self, prog: &Program, requests: &[Vec<Tensor>]) -> Result<BatchOutput> {
        anyhow::ensure!(!requests.is_empty(), "empty batch");
        let analysis = self.batch_analysis(prog);
        let mut metrics = RunMetrics::default();
        if requests.len() > 1 && analysis.eligible() {
            // The cheap per-group binding check: bind member environments
            // (the stacked walk needs them anyway) and verify residual
            // agreement. Mismatched groups decline to the solo loop below.
            if let Some(shape) = group_shape(&prog.module, &analysis, requests) {
                match self.run_grouped(prog, requests, &analysis, shape) {
                    Ok(out) => return Ok(out),
                    // A fault mid-group (compile, transfer, OOM) demotes
                    // the whole batch to sequential solo execution: each
                    // member then descends its own solo ladder, so one
                    // faulted launch cannot fail k requests.
                    Err(_e) => metrics.demotions += 1,
                }
            }
        }
        let mut outputs = Vec::with_capacity(requests.len());
        for r in requests {
            let ExecOutput { outputs: o, metrics: rm } = self.run(prog, r)?;
            metrics += &rm;
            outputs.push(o);
        }
        Ok(BatchOutput { outputs, metrics })
    }

    /// Serve one bindable group through the batch tier pipeline: *replay*
    /// a recorded batch plan when the group shape is known (and its guards
    /// hold), otherwise *interpret* the stacked walk — *recording* a fresh
    /// plan on first sight of the shape.
    fn run_grouped(
        &mut self,
        prog: &Program,
        requests: &[Vec<Tensor>],
        analysis: &BatchAnalysis,
        shape: GroupShape,
    ) -> Result<BatchOutput> {
        // Members of a batched dispatch never pass the solo tiers, so the
        // batch tier records each member's binding vector (residual + its
        // leading extent) in the shared traffic histogram itself.
        for &e in &shape.extents {
            let mut bindings = shape.residual.clone();
            if let Some(bs) = analysis.batch_sym {
                bindings.push((bs, e));
            }
            self.switch.histogram.record_bindings(&bindings);
        }
        if !self.opts.plan_cache {
            return self.run_stacked(prog, requests, analysis, shape, None);
        }
        let key = shape.plan_key(prog.id, self.switch.epoch());
        match self.batch_plans.get(&key).cloned() {
            Some(plan) => {
                if plan.param_guards_hold(requests) {
                    match self.replay_batch(prog, requests, analysis, &shape, &plan) {
                        Ok(Some(mut out)) => {
                            self.batch_plan_stats.hits += 1;
                            out.metrics.launch_elems += plan.launch_elems;
                            out.metrics.padded_elems += plan.padded_elems;
                            return Ok(out);
                        }
                        Ok(None) => {}
                        Err(_e) => {
                            // Device/transfer fault mid-replay: demote the
                            // group to the batched interpret tier. The plan
                            // stays installed (the fault is transient); the
                            // replay's device leases unwound with it, so the
                            // arena accounting is already clean.
                            let mut out =
                                self.run_stacked(prog, requests, analysis, shape, None)?;
                            out.metrics.demotions += 1;
                            return Ok(out);
                        }
                    }
                }
                // Stale shape assumption: this group runs the batched
                // interpret tier; the cached plan stays (the common shape
                // keeps replaying).
                self.batch_plan_stats.guard_misses += 1;
                let mut out = self.run_stacked(prog, requests, analysis, shape, None)?;
                out.metrics.batch_plan_guard_misses += 1;
                Ok(out)
            }
            None => {
                self.batch_plan_stats.misses += 1;
                let mut rec = BatchPlanRecorder::new();
                let mut out =
                    self.run_stacked(prog, requests, analysis, shape, Some(&mut rec))?;
                out.metrics.batch_plan_misses += 1;
                let observed = rec.observed().clone();
                let mut plan = rec.finish(&prog.module);
                // Replays skip the batched interpret tier; the plan carries
                // the recording walk's fused-launch element totals.
                plan.launch_elems = out.metrics.launch_elems;
                plan.padded_elems = out.metrics.padded_elems;
                let mut bindings: HashMap<SymId, i64> = shape.residual.iter().copied().collect();
                if let Some(b) = analysis.batch_sym {
                    bindings.insert(b, *shape.offsets.last().unwrap_or(&0) as i64);
                }
                self.install_batch_plan(key, plan, prog, &bindings, &observed);
                Ok(out)
            }
        }
    }

    /// Install a freshly recorded batch plan: instantiate the program's
    /// symbolic memory plan for this group shape (planned replays then
    /// acquire one extent instead of per-buffer slots), hold a `Reserve`
    /// lease for the planned (or observed) peak, evict FIFO past
    /// `max_plans` (releasing exactly the evicted plan's weight pins), pin
    /// the new plan's weights.
    fn install_batch_plan(
        &mut self,
        key: BatchPlanKey,
        mut plan: BatchPlan,
        prog: &Program,
        bindings: &HashMap<SymId, i64>,
        observed: &HashMap<ValueId, u64>,
    ) {
        if self.opts.device_resident && self.opts.runtime.memory_plan && !observed.is_empty() {
            let mp = self.mem_plan_for(prog);
            plan.memory = mp.instantiate(bindings, self.opts.policy, observed);
        }
        let reserve_bytes = plan
            .memory
            .as_ref()
            .map(|pm| pm.planned_peak_bytes)
            .unwrap_or(plan.device_peak_bytes);
        plan.reserve = self
            .pool
            .device
            .acquire(crate::runtime::buffers::ResidencyClass::Reserve, reserve_bytes, None)
            .ok();
        while self.batch_plans.len() >= self.max_plans.max(1) {
            match self.batch_plan_order.pop_front() {
                Some(old) => {
                    self.batch_plans.remove(&old);
                    for wk in self.batch_plan_pins.remove(&old).unwrap_or_default() {
                        self.library.unpin_weight(&wk);
                    }
                }
                None => break,
            }
        }
        let mut pinned = Vec::new();
        for bs in &plan.steps {
            match bs {
                BatchPlannedStep::Joint { step, .. } => {
                    Self::pin_step_weight(&mut self.library, key.program, step, &mut pinned)
                }
                BatchPlannedStep::Member { per_extent } => {
                    for step in per_extent.values() {
                        Self::pin_step_weight(&mut self.library, key.program, step, &mut pinned);
                    }
                }
            }
        }
        self.batch_plan_pins.insert(key.clone(), pinned);
        self.batch_plans.insert(key.clone(), Arc::new(plan));
        self.batch_plan_order.push_back(key);
        self.batch_plan_stats.entries = self.batch_plans.len();
    }

    /// Pin the cached-weight reference of one planned step, if any —
    /// the single pin rule shared by the solo (`pin_plan_weights`) and
    /// batch plan installers, so what the two caches keep resident can
    /// never silently diverge.
    pub(crate) fn pin_step_weight(
        library: &mut crate::library::GemmLibrary,
        program: u64,
        step: &PlannedStep,
        pinned: &mut Vec<WeightKey>,
    ) {
        if let PlannedStep::LibraryCall { weight: Some(w), .. } = step {
            let key = WeightKey { program, value: w.value };
            if library.pin_weight(&key) {
                pinned.push(key);
            }
        }
    }

    /// The batched interpret tier: one stacked walk of the flow, resolving
    /// symbols and hashing cache keys per step (optionally recording a
    /// [`BatchPlan`] for the group shape).
    fn run_stacked(
        &mut self,
        prog: &Program,
        requests: &[Vec<Tensor>],
        analysis: &BatchAnalysis,
        shape: GroupShape,
        mut rec: Option<&mut BatchPlanRecorder>,
    ) -> Result<BatchOutput> {
        let t_start = Instant::now();
        let m = &prog.module;
        let k = requests.len();
        let mut metrics =
            RunMetrics { policy_epoch: self.switch.epoch(), ..Default::default() };
        let before = self.stats_snapshot();
        let GroupShape { mut envs, extents, offsets, .. } = shape;

        // Stack the entry parameters and bind the batched environment.
        let mut stacked: Vec<Tensor> = Vec::with_capacity(m.params.len());
        for p in 0..m.params.len() {
            let parts: Vec<&Tensor> = requests.iter().map(|r| &r[p]).collect();
            let t = Tensor::concat0(&parts).with_context(|| format!("stacking param {p}"))?;
            metrics.batch_stack_bytes += t.byte_size() as u64;
            stacked.push(t);
        }
        let mut env_b = SymEnv::new();
        env_b.bind_params(m, &stacked)?;
        if rec.is_some() {
            // Log shape reads so the recorder can reuse the solo guard
            // classification (empty for eligible programs).
            env_b.elem_log = Some(Vec::new());
        }

        // Value stores: stacked/shared forms plus per-request forms.
        let n = m.instrs.len();
        let mut joint: Vec<Option<Rc<Tensor>>> = vec![None; n];
        let mut per: Vec<Option<Vec<Rc<Tensor>>>> = vec![None; n];
        let mut stacked_slots: Vec<Option<Tensor>> = stacked.into_iter().map(Some).collect();
        for (id, ins) in m.instrs.iter().enumerate() {
            match &ins.op {
                Op::Param { index } => {
                    joint[id] = stacked_slots[*index].take().map(Rc::new);
                }
                Op::Const { lit, dims } => {
                    joint[id] = Some(Rc::new(Tensor::from_literal(lit, dims)));
                }
                _ => {}
            }
        }

        for (si, step) in prog.steps.iter().enumerate() {
            let mode = analysis.step_modes[si];
            match step {
                Step::Dealloc { value } => {
                    joint[*value] = None;
                    per[*value] = None;
                    if let Some(r) = rec.as_deref_mut() {
                        r.note_dealloc(*value);
                        r.push_joint(PlannedStep::Dealloc { value: *value }, false);
                    }
                }
                _ if mode != BatchMode::PerRequest => {
                    self.stacked_step(
                        prog,
                        step,
                        mode,
                        &mut env_b,
                        &mut joint,
                        &per,
                        &mut metrics,
                        rec.as_deref_mut(),
                    )?;
                }
                _ => {
                    self.solo_step(
                        prog,
                        step,
                        &mut envs,
                        &joint,
                        &mut per,
                        offsets.as_slice(),
                        &extents,
                        analysis,
                        &mut metrics,
                        rec.as_deref_mut(),
                    )?;
                }
            }
        }

        // Split per-request outputs back out.
        let mut outputs: Vec<Vec<Tensor>> =
            (0..k).map(|_| Vec::with_capacity(m.outputs.len())).collect();
        for &o in &m.outputs {
            for (i, out) in outputs.iter_mut().enumerate() {
                let t = per_value(&joint, &mut per, analysis, &offsets, &mut metrics, o, i)
                    .with_context(|| format!("output %{o} was deallocated"))?;
                out.push((*t).clone());
            }
        }

        if let Some(r) = rec.as_deref_mut() {
            r.stash_elem_log(env_b.elem_log.take().unwrap_or_default());
        }
        self.fold_stats(&mut metrics, &before);
        metrics.batched_requests += k as u64;
        metrics.batched_launches += 1;
        metrics.total_time = t_start.elapsed();
        Ok(BatchOutput { outputs, metrics })
    }

    /// One GEMM library call on already-materialized operands, routing
    /// constant weights through the persistent device-side cache — the
    /// shared body of the stacked and per-member batched paths (the
    /// recorder-integrated interpret tier keeps its own copy, which also
    /// serves fingerprint-validated parameter weights). Returns the
    /// resolved library key and weight reference alongside the result so
    /// the batch-plan recorder can capture them.
    fn batched_gemm(
        &mut self,
        prog: &Program,
        value: ValueId,
        a: &Tensor,
        bt: &Tensor,
        metrics: &mut RunMetrics,
    ) -> Result<(Tensor, GemmKey, Option<PlanWeight>)> {
        let m = &prog.module;
        let ins = &m.instrs[value];
        metrics.lib_bytes += (a.byte_size() + bt.byte_size()) as u64;
        let build0 = self.library.stats.build_time;
        let exec0 = self.library.stats.exec_time;
        let key = self.library.key_for(a, bt)?;
        // Constant weights ride the persistent device-side cache — the
        // same entries solo runs populate. Parameter weights can be
        // stacked per batch, so they take the plain host path.
        let weight = if self.opts.device_resident && self.opts.runtime.weight_cache {
            weight_ref_of(m, ins.operands[1]).filter(|w| !w.validate && bt.dtype == DType::F32)
        } else {
            None
        };
        let t = if let Some(w) = &weight {
            let wdev = self.library.weight_device(
                WeightKey { program: prog.id, value: w.value },
                bt,
                &key.rhs_dims(),
                w.validate,
            )?;
            let (dt, actual) = self.library.matmul_device(
                GemmSrc::Host(a),
                GemmSrc::Weight { dt: wdev, actual: &bt.dims },
                key,
            )?;
            self.library.readback(&dt, &actual)?
        } else {
            self.library.matmul_with_key(a, bt, key)?
        };
        metrics.lib_time += self.library.stats.exec_time - exec0;
        metrics.compile_time += self.library.stats.build_time - build0;
        metrics.lib_calls += 1;
        metrics.lib_bytes += t.byte_size() as u64;
        Ok((t, key, weight))
    }

    /// One fused-kernel launch on already-materialized inputs: resolve the
    /// group's extents through `env`, fetch the bucket-keyed kernel, pad,
    /// launch, crop — the shared body of the stacked and per-member
    /// batched paths. Stacked launches are keyed by the *widened* leading
    /// extent, so a batch rides the same (signature, bucket) family solo
    /// traffic compiles; `count_padding` additionally accounts pad-lane
    /// traffic into `batch_padding_bytes` for them. Returns the compiled
    /// kernel and resolved extent scalars alongside the result so the
    /// batch-plan recorder can capture them.
    fn batched_fused(
        &mut self,
        prog: &Program,
        idx: usize,
        env: &mut SymEnv,
        inputs: &[Rc<Tensor>],
        count_padding: bool,
        metrics: &mut RunMetrics,
    ) -> Result<(Tensor, Arc<CompiledKernel>, Vec<i32>)> {
        let m = &prog.module;
        let fl = &prog.fused[idx];
        let mut actual: HashMap<SymId, usize> = HashMap::with_capacity(fl.syms.len());
        for &s in &fl.syms {
            actual.insert(s, env.resolve_dim(m, Dim::Sym(s), &NoVals)?);
        }
        let (kernel, _buckets) = self.cache.get_or_compile(m, &fl.group, &fl.sig, &actual)?;
        let actual_vec: Vec<usize> = fl.syms.iter().map(|s| actual[s]).collect();
        self.switch.histogram.record_site(prog.id, idx, &fl.syms, &actual_vec);
        let spec = &kernel.spec;
        enum Src {
            In(usize),
            Owned(usize),
        }
        let mut owned: Vec<Tensor> = Vec::new();
        let mut srcs: Vec<Src> = Vec::with_capacity(inputs.len() + spec.extent_locals.len());
        for (i, src) in inputs.iter().enumerate() {
            let bucket_elems = spec.input_dims[i].iter().product::<usize>() as u64;
            metrics.launch_elems += bucket_elems;
            if src.dims == spec.input_dims[i] {
                srcs.push(Src::In(i));
                metrics.mem_bytes += src.byte_size() as u64;
            } else {
                metrics.pad_copies += 1;
                metrics.padded_elems +=
                    bucket_elems - src.dims.iter().product::<usize>() as u64;
                let padded = pad_box(
                    src,
                    &spec.input_dims[i],
                    if self.opts.pooled_buffers { Some(&mut self.pool) } else { None },
                )?;
                metrics.mem_bytes += padded.byte_size() as u64;
                if count_padding {
                    metrics.batch_padding_bytes += (padded.byte_size() - src.byte_size()) as u64;
                }
                srcs.push(Src::Owned(owned.len()));
                owned.push(padded);
            }
        }
        let mut extent_vals: Vec<i32> = Vec::with_capacity(spec.extent_locals.len());
        for &li in &spec.extent_locals {
            let v = actual[&fl.syms[li]] as i32;
            extent_vals.push(v);
            srcs.push(Src::Owned(owned.len()));
            owned.push(Tensor::i32(&[], vec![v]));
        }
        let args: Vec<&Tensor> = srcs
            .iter()
            .map(|s| match s {
                Src::In(i) => inputs[*i].as_ref(),
                Src::Owned(i) => &owned[*i],
            })
            .collect();
        for a in &args {
            metrics.h2d_bytes += a.byte_size() as u64;
        }
        let tk = Instant::now();
        let out = kernel
            .exe
            .run(&args, &spec.out_dims, spec.out_dtype)
            .with_context(|| format!("launching fused kernel {} (batched)", spec.name))?;
        metrics.kernel_time += tk.elapsed();
        metrics.mem_kernels += 1;
        drop(args);
        if self.opts.pooled_buffers {
            for a in owned {
                if let Data::F32(v) = a.data {
                    if v.capacity() > 0 {
                        self.pool.free_f32(v);
                    }
                }
            }
        }
        metrics.mem_bytes += out.byte_size() as u64;
        metrics.d2h_bytes += out.byte_size() as u64;
        let actual_out = env.resolve_dims(m, &m.ty(fl.root).dims, &NoVals)?;
        metrics.launch_elems += spec.out_dims.iter().product::<usize>() as u64;
        let out = if out.dims == actual_out {
            out
        } else {
            metrics.pad_copies += 1;
            metrics.padded_elems += (spec.out_dims.iter().product::<usize>()
                - actual_out.iter().product::<usize>()) as u64;
            if count_padding {
                metrics.batch_padding_bytes += (out.byte_size()
                    - actual_out.iter().product::<usize>() * spec.out_dtype.byte_size())
                    as u64;
            }
            crop_box(&out, &actual_out)?
        };
        Ok((out, kernel, extent_vals))
    }

    /// Execute one Stacked/Shared step over the joint value store
    /// (optionally recording its widened resolution into a batch plan).
    #[allow(clippy::too_many_arguments)]
    fn stacked_step(
        &mut self,
        prog: &Program,
        step: &Step,
        mode: BatchMode,
        env_b: &mut SymEnv,
        joint: &mut [Option<Rc<Tensor>>],
        per: &[Option<Vec<Rc<Tensor>>>],
        metrics: &mut RunMetrics,
        rec: Option<&mut BatchPlanRecorder>,
    ) -> Result<()> {
        let m = &prog.module;
        let stacked = mode == BatchMode::Stacked;
        match step {
            Step::EvalHost { value } => {
                let ins = &m.instrs[*value];
                let out_dims = env_b.resolve_dims(m, &ins.ty.dims, &NoVals)?;
                let ops: Vec<Rc<Tensor>> = ins
                    .operands
                    .iter()
                    .map(|&o| joint_value(joint, per, metrics, o))
                    .collect::<Result<_>>()?;
                let refs: Vec<&Tensor> = ops.iter().map(|t| t.as_ref()).collect();
                let t = eval_op(&ins.op, &refs, &out_dims, ins.ty.dtype)
                    .with_context(|| format!("host op %{value} (batched)"))?;
                metrics.host_ops += 1;
                if let Some(r) = rec {
                    r.push_joint(PlannedStep::EvalHost { value: *value, out_dims }, stacked);
                }
                joint[*value] = Some(Rc::new(t));
            }
            Step::Bitcast { value } => {
                let ins = &m.instrs[*value];
                let out_dims = env_b.resolve_dims(m, &ins.ty.dims, &NoVals)?;
                let src = joint_value(joint, per, metrics, ins.operands[0])?;
                metrics.bitcasts += 1;
                let t = (*src).clone().with_dims(&out_dims)?;
                if let Some(r) = rec {
                    r.push_joint(PlannedStep::Bitcast { value: *value, out_dims }, stacked);
                }
                joint[*value] = Some(Rc::new(t));
            }
            Step::LaunchOp { value } => {
                let ins = &m.instrs[*value];
                let out_dims = env_b.resolve_dims(m, &ins.ty.dims, &NoVals)?;
                let ops: Vec<Rc<Tensor>> = ins
                    .operands
                    .iter()
                    .map(|&o| joint_value(joint, per, metrics, o))
                    .collect::<Result<_>>()?;
                let refs: Vec<&Tensor> = ops.iter().map(|t| t.as_ref()).collect();
                for o in &refs {
                    metrics.mem_bytes += o.byte_size() as u64;
                }
                let tk = Instant::now();
                let t = eval_op(&ins.op, &refs, &out_dims, ins.ty.dtype)
                    .with_context(|| format!("singleton kernel %{value} (batched)"))?;
                metrics.kernel_time += tk.elapsed();
                metrics.mem_kernels += 1;
                metrics.mem_bytes += t.byte_size() as u64;
                if let Some(r) = rec {
                    r.push_joint(PlannedStep::LaunchOp { value: *value, out_dims }, stacked);
                }
                joint[*value] = Some(Rc::new(t));
            }
            Step::LibraryCall { value } => {
                let ins = &m.instrs[*value];
                let a = joint_value(joint, per, metrics, ins.operands[0])?;
                let bt = joint_value(joint, per, metrics, ins.operands[1])?;
                let (t, key, weight) = self.batched_gemm(prog, *value, &a, &bt, metrics)?;
                if let Some(r) = rec {
                    if self.opts.device_resident {
                        let out_bytes = (key.batch.max(1) * key.m * key.n * 4) as u64;
                        r.note_device_out(*value, out_bytes);
                    }
                    r.push_joint(
                        PlannedStep::LibraryCall { value: *value, key, weight },
                        stacked,
                    );
                }
                joint[*value] = Some(Rc::new(t));
            }
            Step::LaunchFused { idx } => {
                let fl = &prog.fused[*idx];
                let ins_rc: Vec<Rc<Tensor>> = fl
                    .inputs
                    .iter()
                    .map(|&v| joint_value(joint, per, metrics, v))
                    .collect::<Result<_>>()?;
                let (out, kernel, extent_vals) =
                    self.batched_fused(prog, *idx, env_b, &ins_rc, stacked, metrics)?;
                if let Some(r) = rec {
                    let extents_host: Vec<Tensor> =
                        extent_vals.iter().map(|&v| Tensor::i32(&[], vec![v])).collect();
                    let extents_dev = if self.opts.device_resident {
                        extents_host
                            .iter()
                            .map(|t| self.device.h2d(t).map(Arc::new))
                            .collect::<Result<Vec<_>>>()?
                    } else {
                        Vec::new()
                    };
                    if self.opts.device_resident {
                        let spec = &kernel.spec;
                        let out_bytes = (spec.out_dims.iter().product::<usize>()
                            * spec.out_dtype.byte_size())
                            as u64;
                        r.note_device_out(fl.root, out_bytes);
                    }
                    r.push_joint(
                        PlannedStep::LaunchFused {
                            idx: *idx,
                            kernel,
                            extents_host,
                            extents_dev,
                            out_actual: out.dims.clone(),
                        },
                        stacked,
                    );
                }
                joint[fl.root] = Some(Rc::new(out));
            }
            Step::Dealloc { .. } => unreachable!("handled by the caller"),
        }
        Ok(())
    }

    /// Execute one PerRequest step: once per batch member, with that
    /// member's own environment — exactly the solo interpret semantics.
    /// When recording, one sub-record is captured per distinct member
    /// extent (residuals agree, so the extent determines the resolution).
    #[allow(clippy::too_many_arguments)]
    fn solo_step(
        &mut self,
        prog: &Program,
        step: &Step,
        envs: &mut [SymEnv],
        joint: &[Option<Rc<Tensor>>],
        per: &mut [Option<Vec<Rc<Tensor>>>],
        offsets: &[usize],
        extents: &[i64],
        analysis: &BatchAnalysis,
        metrics: &mut RunMetrics,
        rec: Option<&mut BatchPlanRecorder>,
    ) -> Result<()> {
        let m = &prog.module;
        let k = envs.len();
        let recording = rec.is_some();
        let mut per_rec: HashMap<i64, PlannedStep> = HashMap::new();
        let value = match step {
            Step::EvalHost { value }
            | Step::Bitcast { value }
            | Step::LaunchOp { value }
            | Step::LibraryCall { value } => *value,
            Step::LaunchFused { idx } => prog.fused[*idx].root,
            Step::Dealloc { .. } => unreachable!("handled by the caller"),
        };
        let mut results: Vec<Rc<Tensor>> = Vec::with_capacity(k);
        for i in 0..k {
            let env = &mut envs[i];
            let capture = recording && !per_rec.contains_key(&extents[i]);
            let t = match step {
                Step::EvalHost { value } | Step::LaunchOp { value } => {
                    let ins = &m.instrs[*value];
                    let out_dims = env.resolve_dims(m, &ins.ty.dims, &NoVals)?;
                    let ops: Vec<Rc<Tensor>> = ins
                        .operands
                        .iter()
                        .map(|&o| per_value(joint, per, analysis, offsets, metrics, o, i))
                        .collect::<Result<_>>()?;
                    let refs: Vec<&Tensor> = ops.iter().map(|t| t.as_ref()).collect();
                    if capture {
                        let rs = if matches!(step, Step::LaunchOp { .. }) {
                            PlannedStep::LaunchOp { value: *value, out_dims: out_dims.clone() }
                        } else {
                            PlannedStep::EvalHost { value: *value, out_dims: out_dims.clone() }
                        };
                        per_rec.insert(extents[i], rs);
                    }
                    if matches!(step, Step::LaunchOp { .. }) {
                        for o in &refs {
                            metrics.mem_bytes += o.byte_size() as u64;
                        }
                        let tk = Instant::now();
                        let t = eval_op(&ins.op, &refs, &out_dims, ins.ty.dtype)
                            .with_context(|| format!("singleton kernel %{value} (member {i})"))?;
                        metrics.kernel_time += tk.elapsed();
                        metrics.mem_kernels += 1;
                        metrics.mem_bytes += t.byte_size() as u64;
                        t
                    } else {
                        metrics.host_ops += 1;
                        eval_op(&ins.op, &refs, &out_dims, ins.ty.dtype)
                            .with_context(|| format!("host op %{value} (member {i})"))?
                    }
                }
                Step::Bitcast { value } => {
                    let ins = &m.instrs[*value];
                    let out_dims = env.resolve_dims(m, &ins.ty.dims, &NoVals)?;
                    let src =
                        per_value(joint, per, analysis, offsets, metrics, ins.operands[0], i)?;
                    metrics.bitcasts += 1;
                    if capture {
                        per_rec.insert(
                            extents[i],
                            PlannedStep::Bitcast { value: *value, out_dims: out_dims.clone() },
                        );
                    }
                    (*src).clone().with_dims(&out_dims)?
                }
                Step::LibraryCall { value } => {
                    let ins = &m.instrs[*value];
                    let a = per_value(joint, per, analysis, offsets, metrics, ins.operands[0], i)?;
                    let bt = per_value(joint, per, analysis, offsets, metrics, ins.operands[1], i)?;
                    let (t, key, weight) = self
                        .batched_gemm(prog, *value, &a, &bt, metrics)
                        .with_context(|| format!("library call %{value} (member {i})"))?;
                    if capture {
                        per_rec.insert(
                            extents[i],
                            PlannedStep::LibraryCall { value: *value, key, weight },
                        );
                    }
                    t
                }
                Step::LaunchFused { idx } => {
                    let fl = &prog.fused[*idx];
                    let ins_rc: Vec<Rc<Tensor>> = fl
                        .inputs
                        .iter()
                        .map(|&v| per_value(joint, per, analysis, offsets, metrics, v, i))
                        .collect::<Result<_>>()?;
                    let (t, kernel, extent_vals) = self
                        .batched_fused(prog, *idx, env, &ins_rc, false, metrics)
                        .with_context(|| format!("fused launch {idx} (member {i})"))?;
                    if capture {
                        let extents_host: Vec<Tensor> =
                            extent_vals.iter().map(|&v| Tensor::i32(&[], vec![v])).collect();
                        // Member sub-records replay host-side (their values
                        // cross in and out of the per-request world by row
                        // slicing), so no device extent scalars are kept.
                        per_rec.insert(
                            extents[i],
                            PlannedStep::LaunchFused {
                                idx: *idx,
                                kernel,
                                extents_host,
                                extents_dev: Vec::new(),
                                out_actual: t.dims.clone(),
                            },
                        );
                    }
                    t
                }
                Step::Dealloc { .. } => unreachable!("handled by the caller"),
            };
            results.push(Rc::new(t));
        }
        per[value] = Some(results);
        if let Some(r) = rec {
            r.push_member(per_rec);
        }
        Ok(())
    }
}

// --- batched plan replay --------------------------------------------------

/// Materialize a host view of a joint value during batch replay: the host
/// slot, a readback (+ crop) of the device-resident joint buffer, or a
/// concatenation of the per-request parts.
fn replay_joint_value(
    device: &Device,
    joint: &mut [Option<Rc<Tensor>>],
    jdev: &[Option<DevSlot>],
    per: &[Option<Vec<Rc<Tensor>>>],
    metrics: &mut RunMetrics,
    v: ValueId,
) -> Result<Rc<Tensor>> {
    if let Some(t) = &joint[v] {
        return Ok(t.clone());
    }
    if let Some(d) = jdev[v].as_ref() {
        let full = device.d2h(&d.dt)?;
        metrics.d2h_bytes += full.byte_size() as u64;
        let t = if full.dims == d.actual {
            full
        } else {
            metrics.pad_copies += 1;
            crop_box(&full, &d.actual)?
        };
        let rc = Rc::new(t);
        joint[v] = Some(rc.clone());
        return Ok(rc);
    }
    let parts = per[v]
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("value %{v} has no live batched form"))?;
    let refs: Vec<&Tensor> = parts.iter().map(|r| r.as_ref()).collect();
    let t = Tensor::concat0(&refs).with_context(|| format!("stacking value %{v} (replay)"))?;
    metrics.batch_stack_bytes += t.byte_size() as u64;
    let rc = Rc::new(t);
    joint[v] = Some(rc.clone());
    Ok(rc)
}

/// Materialize request `i`'s view of a value during batch replay: the
/// per-request slot, the shared joint tensor, or a row slice of the
/// stacked form (read back from device first when needed).
#[allow(clippy::too_many_arguments)]
fn replay_per_value(
    device: &Device,
    joint: &mut [Option<Rc<Tensor>>],
    jdev: &[Option<DevSlot>],
    per: &mut [Option<Vec<Rc<Tensor>>>],
    analysis: &BatchAnalysis,
    offsets: &[usize],
    metrics: &mut RunMetrics,
    v: ValueId,
    i: usize,
) -> Result<Rc<Tensor>> {
    if let Some(parts) = &per[v] {
        return Ok(parts[i].clone());
    }
    let t = replay_joint_value(device, joint, jdev, &*per, metrics, v)?;
    if analysis.value_modes[v] == BatchMode::Shared {
        return Ok(t);
    }
    let k = offsets.len() - 1;
    let mut parts = Vec::with_capacity(k);
    for j in 0..k {
        let rows = offsets[j + 1] - offsets[j];
        let s = t
            .slice0(offsets[j], rows)
            .with_context(|| format!("splitting value %{v} for request {j} (replay)"))?;
        metrics.batch_stack_bytes += s.byte_size() as u64;
        parts.push(Rc::new(s));
    }
    let out = parts[i].clone();
    per[v] = Some(parts);
    Ok(out)
}

impl Executor {
    /// Host-path replay of one recorded fused launch over materialized
    /// inputs: recorded kernel, recorded extent scalars, recorded crop —
    /// no resolution, no hashing. The member path of batched replays (and
    /// the joint path when `device_resident` is off).
    #[allow(clippy::too_many_arguments)]
    fn replay_fused_host(
        &mut self,
        kernel: &Arc<CompiledKernel>,
        inputs: &[Rc<Tensor>],
        extents_host: &[Tensor],
        out_actual: &[usize],
        count_padding: bool,
        metrics: &mut RunMetrics,
        label: &str,
    ) -> Result<Tensor> {
        let spec = &kernel.spec;
        // The recorded kernel replaces signature hashing and the bucket
        // lookup; account it as a hit so reuse stats stay meaningful.
        self.cache.stats.hits += 1;
        enum Src {
            In(usize),
            Owned(usize),
        }
        let mut owned: Vec<Tensor> = Vec::new();
        let mut srcs: Vec<Src> = Vec::with_capacity(inputs.len());
        for (i, t) in inputs.iter().enumerate() {
            if t.dims == spec.input_dims[i] {
                srcs.push(Src::In(i));
                metrics.mem_bytes += t.byte_size() as u64;
            } else {
                metrics.pad_copies += 1;
                let padded = pad_box(
                    t,
                    &spec.input_dims[i],
                    if self.opts.pooled_buffers { Some(&mut self.pool) } else { None },
                )?;
                metrics.mem_bytes += padded.byte_size() as u64;
                if count_padding {
                    metrics.batch_padding_bytes += (padded.byte_size() - t.byte_size()) as u64;
                }
                srcs.push(Src::Owned(owned.len()));
                owned.push(padded);
            }
        }
        let args: Vec<&Tensor> = srcs
            .iter()
            .map(|s| match s {
                Src::In(i) => inputs[*i].as_ref(),
                Src::Owned(i) => &owned[*i],
            })
            .chain(extents_host.iter())
            .collect();
        for a in &args {
            metrics.h2d_bytes += a.byte_size() as u64;
        }
        let tk = Instant::now();
        let out = kernel
            .exe
            .run(&args, &spec.out_dims, spec.out_dtype)
            .with_context(|| format!("replaying fused kernel {} ({label})", spec.name))?;
        metrics.kernel_time += tk.elapsed();
        metrics.mem_kernels += 1;
        drop(args);
        if self.opts.pooled_buffers {
            for a in owned {
                if let Data::F32(v) = a.data {
                    if v.capacity() > 0 {
                        self.pool.free_f32(v);
                    }
                }
            }
        }
        metrics.mem_bytes += out.byte_size() as u64;
        metrics.d2h_bytes += out.byte_size() as u64;
        if out.dims.as_slice() == out_actual {
            Ok(out)
        } else {
            metrics.pad_copies += 1;
            if count_padding {
                // Same output pad-lane accounting as the interpret tier's
                // `batched_fused`, so pad-waste reporting does not dip
                // when plans start replaying.
                metrics.batch_padding_bytes += (out.byte_size()
                    - out_actual.iter().product::<usize>() * spec.out_dtype.byte_size())
                    as u64;
            }
            crop_box(&out, out_actual)
        }
    }

    /// Replay one recorded GEMM on host-materialized operands, serving the
    /// recorded weight from the persistent device cache. Mirrors
    /// `batched_gemm`'s accounting minus the key/weight derivation.
    fn replay_gemm_host(
        &mut self,
        prog: &Program,
        key: GemmKey,
        weight: Option<PlanWeight>,
        a: &Tensor,
        bt: &Tensor,
        metrics: &mut RunMetrics,
    ) -> Result<Tensor> {
        let build0 = self.library.stats.build_time;
        let exec0 = self.library.stats.exec_time;
        metrics.lib_bytes += (a.byte_size() + bt.byte_size()) as u64;
        let t = if let Some(w) = &weight {
            let wdev = self.library.weight_device(
                WeightKey { program: prog.id, value: w.value },
                bt,
                &key.rhs_dims(),
                w.validate,
            )?;
            let (dt, actual) = self.library.matmul_device(
                GemmSrc::Host(a),
                GemmSrc::Weight { dt: wdev, actual: &bt.dims },
                key,
            )?;
            self.library.readback(&dt, &actual)?
        } else {
            self.library.matmul_with_key(a, bt, key)?
        };
        metrics.lib_time += self.library.stats.exec_time - exec0;
        metrics.compile_time += self.library.stats.build_time - build0;
        metrics.lib_calls += 1;
        metrics.lib_bytes += t.byte_size() as u64;
        Ok(t)
    }

    /// The batch replay tier: walk a recorded [`BatchPlan`] — no per-step
    /// symbol resolution, no signature hashing, no mode branching — with
    /// Stacked/Shared fused-kernel and GEMM results chained dev→dev
    /// through persistent device buffers. Only member crossings, host-op
    /// operands, and program outputs read back to the host. Returns
    /// `Ok(None)` when a recorded host-op guard fails mid-walk (the caller
    /// then serves the group through the batched interpret tier).
    fn replay_batch(
        &mut self,
        prog: &Program,
        requests: &[Vec<Tensor>],
        analysis: &BatchAnalysis,
        shape: &GroupShape,
        plan: &BatchPlan,
    ) -> Result<Option<BatchOutput>> {
        let t_start = Instant::now();
        let m = &prog.module;
        let k = requests.len();
        let device = self.device.clone();
        let mut metrics =
            RunMetrics { policy_epoch: self.switch.epoch(), ..Default::default() };
        let before = self.stats_snapshot();

        // Seed the joint store: stacked parameters + constants (the same
        // assembly the interpret tier performs).
        let n = m.instrs.len();
        let mut joint: Vec<Option<Rc<Tensor>>> = vec![None; n];
        let mut jdev: Vec<Option<DevSlot>> = vec![None; n];
        let mut per: Vec<Option<Vec<Rc<Tensor>>>> = vec![None; n];
        for (id, ins) in m.instrs.iter().enumerate() {
            match &ins.op {
                Op::Param { index } => {
                    let parts: Vec<&Tensor> = requests.iter().map(|r| &r[*index]).collect();
                    let t = Tensor::concat0(&parts)
                        .with_context(|| format!("stacking param {index} (replay)"))?;
                    metrics.batch_stack_bytes += t.byte_size() as u64;
                    joint[id] = Some(Rc::new(t));
                }
                Op::Const { lit, dims } => {
                    joint[id] = Some(Rc::new(Tensor::from_literal(lit, dims)));
                }
                _ => {}
            }
        }
        // Planned replay: one extent lease fronts the whole walk (the only
        // armed OOM seam); the per-buffer acquires below are skipped.
        let _extent: Option<crate::runtime::buffers::ArenaLease> = match &plan.memory {
            Some(pm) => Some(self.pool.device.acquire(
                crate::runtime::buffers::ResidencyClass::Batch,
                pm.planned_peak_bytes,
                self.device.faults().map(|f| f.as_ref()),
            )?),
            None => None,
        };
        let walked = self.replay_walk(
            prog,
            analysis,
            shape,
            plan,
            device,
            &mut joint,
            &mut jdev,
            &mut per,
            &mut metrics,
        );
        // Drop every surviving joint device slot no matter how the walk
        // ended — each slot's lease unwinds its arena accounting, so error
        // and guard-abort paths cannot leak (Dealloc steps dropped their
        // slots already; those are gone from `jdev`).
        for d in jdev.iter_mut() {
            *d = None;
        }
        let outputs = match walked? {
            Some(o) => o,
            None => return Ok(None),
        };

        self.fold_stats(&mut metrics, &before);
        metrics.batch_dev_resident_bytes = self
            .pool
            .device
            .footprint_high_water(crate::runtime::buffers::ResidencyClass::Batch);
        if let Some(pm) = &plan.memory {
            metrics.planned_peak_bytes = pm.planned_peak_bytes;
            metrics.mem_plan_reuse_bytes += pm.reuse_bytes;
        }
        metrics.batched_requests += k as u64;
        metrics.batched_launches += 1;
        metrics.batch_plan_hits += 1;
        metrics.total_time = t_start.elapsed();
        Ok(Some(BatchOutput { outputs, metrics }))
    }

    /// The step walk of [`replay_batch`]: executes every recorded step and
    /// assembles per-request outputs. Returns `Ok(None)` on a host-guard
    /// miss. Deliberately does NOT release surviving `jdev` slots — the
    /// caller does, identically on success, guard-miss, and error paths.
    #[allow(clippy::too_many_arguments)]
    fn replay_walk(
        &mut self,
        prog: &Program,
        analysis: &BatchAnalysis,
        shape: &GroupShape,
        plan: &BatchPlan,
        device: Arc<Device>,
        joint: &mut Vec<Option<Rc<Tensor>>>,
        jdev: &mut Vec<Option<DevSlot>>,
        per: &mut Vec<Option<Vec<Rc<Tensor>>>>,
        metrics: &mut RunMetrics,
    ) -> Result<Option<Vec<Vec<Tensor>>>> {
        let m = &prog.module;
        let k = shape.extents.len();
        let offsets = shape.offsets.as_slice();
        let planned = plan.memory.is_some();

        for bstep in &plan.steps {
            match bstep {
                BatchPlannedStep::Joint { step, stacked } => match step {
                    PlannedStep::EvalHost { value, out_dims } => {
                        let ins = &m.instrs[*value];
                        let mut ops: Vec<Rc<Tensor>> = Vec::with_capacity(ins.operands.len());
                        for &o in &ins.operands {
                            ops.push(replay_joint_value(
                                &device,
                                &mut joint,
                                &jdev,
                                &per,
                                &mut metrics,
                                o,
                            )?);
                        }
                        let refs: Vec<&Tensor> = ops.iter().map(|t| t.as_ref()).collect();
                        let t = eval_op(&ins.op, &refs, out_dims, ins.ty.dtype)
                            .with_context(|| format!("host op %{value} (batch replay)"))?;
                        metrics.host_ops += 1;
                        let t = Rc::new(t);
                        if let Some(gs) = plan.host_guards.get(value) {
                            if !host_guards_hold(gs, &t) {
                                // Stale shape assumption: the caller
                                // releases the arena accounting and
                                // discards the partial metrics.
                                return Ok(None);
                            }
                        }
                        joint[*value] = Some(t);
                    }
                    PlannedStep::Bitcast { value, out_dims } => {
                        let src = replay_joint_value(
                            &device,
                            &mut joint,
                            &jdev,
                            &per,
                            &mut metrics,
                            m.instrs[*value].operands[0],
                        )?;
                        metrics.bitcasts += 1;
                        joint[*value] = Some(Rc::new((*src).clone().with_dims(out_dims)?));
                    }
                    PlannedStep::LaunchOp { value, out_dims } => {
                        let ins = &m.instrs[*value];
                        let mut ops: Vec<Rc<Tensor>> = Vec::with_capacity(ins.operands.len());
                        for &o in &ins.operands {
                            ops.push(replay_joint_value(
                                &device,
                                &mut joint,
                                &jdev,
                                &per,
                                &mut metrics,
                                o,
                            )?);
                        }
                        let refs: Vec<&Tensor> = ops.iter().map(|t| t.as_ref()).collect();
                        for o in &refs {
                            metrics.mem_bytes += o.byte_size() as u64;
                        }
                        let tk = Instant::now();
                        let t = eval_op(&ins.op, &refs, out_dims, ins.ty.dtype).with_context(
                            || format!("singleton kernel %{value} (batch replay)"),
                        )?;
                        metrics.kernel_time += tk.elapsed();
                        metrics.mem_kernels += 1;
                        metrics.mem_bytes += t.byte_size() as u64;
                        joint[*value] = Some(Rc::new(t));
                    }
                    PlannedStep::LibraryCall { value, key, weight } => {
                        let ins = &m.instrs[*value];
                        let (a_id, b_id) = (ins.operands[0], ins.operands[1]);
                        if self.opts.device_resident {
                            // Chain dev→dev wherever a device-resident joint
                            // operand exists; the library adapts buckets and
                            // masks garbage pad lanes on device. The result
                            // stays device-resident for the next launch.
                            let build0 = self.library.stats.build_time;
                            let exec0 = self.library.stats.exec_time;
                            let a_host = if jdev[a_id].is_none() {
                                Some(replay_joint_value(
                                    &device,
                                    &mut joint,
                                    &jdev,
                                    &per,
                                    &mut metrics,
                                    a_id,
                                )?)
                            } else {
                                None
                            };
                            let w_dev = if let Some(w) = weight {
                                let bt = replay_joint_value(
                                    &device,
                                    &mut joint,
                                    &jdev,
                                    &per,
                                    &mut metrics,
                                    b_id,
                                )?;
                                let dt = self.library.weight_device(
                                    WeightKey { program: prog.id, value: w.value },
                                    &bt,
                                    &key.rhs_dims(),
                                    w.validate,
                                )?;
                                let dims = bt.dims.clone();
                                Some((dt, dims))
                            } else {
                                None
                            };
                            let b_host = if w_dev.is_none() && jdev[b_id].is_none() {
                                Some(replay_joint_value(
                                    &device,
                                    &mut joint,
                                    &jdev,
                                    &per,
                                    &mut metrics,
                                    b_id,
                                )?)
                            } else {
                                None
                            };
                            let src_a = match (&a_host, jdev[a_id].as_ref()) {
                                (Some(t), _) => GemmSrc::Host(t),
                                (None, Some(s)) => GemmSrc::Dev {
                                    dt: &s.dt,
                                    actual: &s.actual,
                                    zero_padded: s.zero_padded,
                                },
                                _ => unreachable!("lhs has neither host nor device value"),
                            };
                            let src_b = match (&w_dev, &b_host, jdev[b_id].as_ref()) {
                                (Some((dt, dims)), _, _) => {
                                    GemmSrc::Weight { dt: dt.clone(), actual: dims }
                                }
                                (None, Some(t), _) => GemmSrc::Host(t),
                                (None, None, Some(s)) => GemmSrc::Dev {
                                    dt: &s.dt,
                                    actual: &s.actual,
                                    zero_padded: s.zero_padded,
                                },
                                _ => unreachable!("rhs has neither host nor device value"),
                            };
                            let a_bytes = src_a.actual_byte_size();
                            let b_bytes = src_b.actual_byte_size();
                            let (dt, actual) = self.library.matmul_device(src_a, src_b, *key)?;
                            metrics.lib_bytes += a_bytes + b_bytes;
                            metrics.lib_bytes +=
                                (actual.iter().product::<usize>() * 4) as u64;
                            metrics.lib_time += self.library.stats.exec_time - exec0;
                            metrics.compile_time += self.library.stats.build_time - build0;
                            metrics.lib_calls += 1;
                            let lease = if planned {
                                None
                            } else {
                                Some(self.pool.device.acquire(
                                    crate::runtime::buffers::ResidencyClass::Batch,
                                    dt.byte_size() as u64,
                                    self.device.faults().map(|f| f.as_ref()),
                                )?)
                            };
                            jdev[*value] =
                                Some(DevSlot { dt, actual, zero_padded: true, lease });
                        } else {
                            let a = replay_joint_value(
                                &device,
                                &mut joint,
                                &jdev,
                                &per,
                                &mut metrics,
                                a_id,
                            )?;
                            let bt = replay_joint_value(
                                &device,
                                &mut joint,
                                &jdev,
                                &per,
                                &mut metrics,
                                b_id,
                            )?;
                            let t =
                                self.replay_gemm_host(prog, *key, *weight, &a, &bt, &mut metrics)?;
                            joint[*value] = Some(Rc::new(t));
                        }
                    }
                    PlannedStep::LaunchFused {
                        idx,
                        kernel,
                        extents_host,
                        extents_dev,
                        out_actual,
                    } => {
                        let fl = &prog.fused[*idx];
                        let spec = &kernel.spec;
                        if self.opts.device_resident {
                            self.cache.stats.hits += 1;
                            enum Src {
                                Owned(usize),
                                Slot(usize),
                                Ext(usize),
                            }
                            let mut owned: Vec<DeviceTensor> = Vec::new();
                            let mut srcs: Vec<Src> =
                                Vec::with_capacity(fl.inputs.len() + extents_dev.len());
                            for (ii, &v) in fl.inputs.iter().enumerate() {
                                let expected = &spec.input_dims[ii];
                                if let Some(d) = jdev[v].as_ref() {
                                    if &d.dt.dims == expected {
                                        // Device-resident chaining: consume
                                        // the producer's bucket-shaped
                                        // buffer in place.
                                        metrics.mem_bytes += d.dt.byte_size() as u64;
                                        srcs.push(Src::Slot(v));
                                        continue;
                                    }
                                }
                                let t = replay_joint_value(
                                    &device,
                                    &mut joint,
                                    &jdev,
                                    &per,
                                    &mut metrics,
                                    v,
                                )?;
                                let up = if t.dims == *expected {
                                    device.h2d(&t)?
                                } else {
                                    metrics.pad_copies += 1;
                                    let padded = pad_box(
                                        &t,
                                        expected,
                                        if self.opts.pooled_buffers {
                                            Some(&mut self.pool)
                                        } else {
                                            None
                                        },
                                    )?;
                                    if *stacked {
                                        metrics.batch_padding_bytes +=
                                            (padded.byte_size() - t.byte_size()) as u64;
                                    }
                                    let dt = device.h2d(&padded)?;
                                    if self.opts.pooled_buffers {
                                        if let Data::F32(v) = padded.data {
                                            if v.capacity() > 0 {
                                                self.pool.free_f32(v);
                                            }
                                        }
                                    }
                                    dt
                                };
                                metrics.mem_bytes += up.byte_size() as u64;
                                metrics.h2d_bytes += up.byte_size() as u64;
                                srcs.push(Src::Owned(owned.len()));
                                owned.push(up);
                            }
                            for ii in 0..extents_dev.len() {
                                srcs.push(Src::Ext(ii));
                            }
                            let args: Vec<&DeviceTensor> = srcs
                                .iter()
                                .map(|s| match s {
                                    Src::Owned(ii) => &owned[*ii],
                                    Src::Slot(v) => &jdev[*v].as_ref().unwrap().dt,
                                    Src::Ext(ii) => extents_dev[*ii].as_ref(),
                                })
                                .collect();
                            let tk = Instant::now();
                            let out = kernel
                                .exe
                                .run_on_device(&args, &spec.out_dims, spec.out_dtype)
                                .with_context(|| {
                                    format!("replaying fused kernel {} (batch)", spec.name)
                                })?;
                            metrics.kernel_time += tk.elapsed();
                            metrics.mem_kernels += 1;
                            metrics.mem_bytes += out.byte_size() as u64;
                            drop(args);
                            let bytes = out.byte_size() as u64;
                            if *stacked {
                                // The bucket-shaped output's pad lanes stay
                                // resident (cropped only on readback):
                                // account them like the interpret tier's
                                // output crop does.
                                let actual_bytes = out_actual.iter().product::<usize>()
                                    * spec.out_dtype.byte_size();
                                metrics.batch_padding_bytes +=
                                    (out.byte_size() - actual_bytes) as u64;
                            }
                            let lease = if planned {
                                None
                            } else {
                                Some(self.pool.device.acquire(
                                    crate::runtime::buffers::ResidencyClass::Batch,
                                    bytes,
                                    self.device.faults().map(|f| f.as_ref()),
                                )?)
                            };
                            jdev[fl.root] = Some(DevSlot {
                                dt: out,
                                actual: out_actual.clone(),
                                zero_padded: false,
                                lease,
                            });
                        } else {
                            let mut ins_rc: Vec<Rc<Tensor>> =
                                Vec::with_capacity(fl.inputs.len());
                            for &v in &fl.inputs {
                                ins_rc.push(replay_joint_value(
                                    &device,
                                    &mut joint,
                                    &jdev,
                                    &per,
                                    &mut metrics,
                                    v,
                                )?);
                            }
                            let out = self.replay_fused_host(
                                kernel,
                                &ins_rc,
                                extents_host,
                                out_actual,
                                *stacked,
                                &mut metrics,
                                "batch",
                            )?;
                            joint[fl.root] = Some(Rc::new(out));
                        }
                    }
                    PlannedStep::Dealloc { value } => {
                        jdev[*value] = None;
                        joint[*value] = None;
                        per[*value] = None;
                    }
                },
                BatchPlannedStep::Member { per_extent } => {
                    let mut results: Vec<Rc<Tensor>> = Vec::with_capacity(k);
                    let mut out_value: Option<ValueId> = None;
                    for i in 0..k {
                        let step = per_extent.get(&shape.extents[i]).ok_or_else(|| {
                            anyhow::anyhow!(
                                "batch plan missing member record for extent {}",
                                shape.extents[i]
                            )
                        })?;
                        let t = match step {
                            PlannedStep::EvalHost { value, out_dims } => {
                                out_value = Some(*value);
                                let ins = &m.instrs[*value];
                                let mut ops: Vec<Rc<Tensor>> =
                                    Vec::with_capacity(ins.operands.len());
                                for &o in &ins.operands {
                                    ops.push(replay_per_value(
                                        &device,
                                        &mut joint,
                                        &jdev,
                                        &mut per,
                                        analysis,
                                        offsets,
                                        &mut metrics,
                                        o,
                                        i,
                                    )?);
                                }
                                let refs: Vec<&Tensor> =
                                    ops.iter().map(|t| t.as_ref()).collect();
                                metrics.host_ops += 1;
                                eval_op(&ins.op, &refs, out_dims, ins.ty.dtype).with_context(
                                    || format!("host op %{value} (member {i}, replay)"),
                                )?
                            }
                            PlannedStep::LaunchOp { value, out_dims } => {
                                out_value = Some(*value);
                                let ins = &m.instrs[*value];
                                let mut ops: Vec<Rc<Tensor>> =
                                    Vec::with_capacity(ins.operands.len());
                                for &o in &ins.operands {
                                    ops.push(replay_per_value(
                                        &device,
                                        &mut joint,
                                        &jdev,
                                        &mut per,
                                        analysis,
                                        offsets,
                                        &mut metrics,
                                        o,
                                        i,
                                    )?);
                                }
                                let refs: Vec<&Tensor> =
                                    ops.iter().map(|t| t.as_ref()).collect();
                                for o in &refs {
                                    metrics.mem_bytes += o.byte_size() as u64;
                                }
                                let tk = Instant::now();
                                let t = eval_op(&ins.op, &refs, out_dims, ins.ty.dtype)
                                    .with_context(|| {
                                        format!("singleton kernel %{value} (member {i}, replay)")
                                    })?;
                                metrics.kernel_time += tk.elapsed();
                                metrics.mem_kernels += 1;
                                metrics.mem_bytes += t.byte_size() as u64;
                                t
                            }
                            PlannedStep::Bitcast { value, out_dims } => {
                                out_value = Some(*value);
                                let src = replay_per_value(
                                    &device,
                                    &mut joint,
                                    &jdev,
                                    &mut per,
                                    analysis,
                                    offsets,
                                    &mut metrics,
                                    m.instrs[*value].operands[0],
                                    i,
                                )?;
                                metrics.bitcasts += 1;
                                (*src).clone().with_dims(out_dims)?
                            }
                            PlannedStep::LibraryCall { value, key, weight } => {
                                out_value = Some(*value);
                                let ins = &m.instrs[*value];
                                let a = replay_per_value(
                                    &device,
                                    &mut joint,
                                    &jdev,
                                    &mut per,
                                    analysis,
                                    offsets,
                                    &mut metrics,
                                    ins.operands[0],
                                    i,
                                )?;
                                let bt = replay_per_value(
                                    &device,
                                    &mut joint,
                                    &jdev,
                                    &mut per,
                                    analysis,
                                    offsets,
                                    &mut metrics,
                                    ins.operands[1],
                                    i,
                                )?;
                                self.replay_gemm_host(prog, *key, *weight, &a, &bt, &mut metrics)
                                    .with_context(|| {
                                        format!("library call %{value} (member {i}, replay)")
                                    })?
                            }
                            PlannedStep::LaunchFused {
                                idx,
                                kernel,
                                extents_host,
                                out_actual,
                                ..
                            } => {
                                out_value = Some(prog.fused[*idx].root);
                                let fl = &prog.fused[*idx];
                                let mut ins_rc: Vec<Rc<Tensor>> =
                                    Vec::with_capacity(fl.inputs.len());
                                for &v in &fl.inputs {
                                    ins_rc.push(replay_per_value(
                                        &device,
                                        &mut joint,
                                        &jdev,
                                        &mut per,
                                        analysis,
                                        offsets,
                                        &mut metrics,
                                        v,
                                        i,
                                    )?);
                                }
                                self.replay_fused_host(
                                    kernel,
                                    &ins_rc,
                                    extents_host,
                                    out_actual,
                                    false,
                                    &mut metrics,
                                    "member",
                                )?
                            }
                            PlannedStep::Dealloc { .. } => {
                                unreachable!("member steps produce values")
                            }
                        };
                        results.push(Rc::new(t));
                    }
                    per[out_value.expect("batches have at least one member")] = Some(results);
                }
            }
        }

        // Split per-request outputs back out (reading joint device
        // residents back exactly once).
        let mut outputs: Vec<Vec<Tensor>> =
            (0..k).map(|_| Vec::with_capacity(m.outputs.len())).collect();
        for &o in &m.outputs {
            for (i, out) in outputs.iter_mut().enumerate() {
                let t = replay_per_value(
                    &device,
                    &mut joint,
                    &jdev,
                    &mut per,
                    analysis,
                    offsets,
                    &mut metrics,
                    o,
                    i,
                )
                .with_context(|| format!("output %{o} was deallocated"))?;
                out.push((*t).clone());
            }
        }
        Ok(Some(outputs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::Builder;
    use crate::fusion::{plan, FusionOptions};
    use crate::program::generate;
    use crate::runtime::executor::ExecOptions;
    use crate::runtime::pjrt::Device;
    use crate::util::prng::Prng;

    fn executor() -> Executor {
        Executor::new(Arc::new(Device::cpu().unwrap()), ExecOptions::default())
    }

    /// Solo interpret-only reference (no plan cache, host-resident).
    fn executor_no_plans() -> Executor {
        Executor::new(
            Arc::new(Device::cpu().unwrap()),
            ExecOptions { plan_cache: false, device_resident: false, ..Default::default() },
        )
    }

    fn program_of(m: Module) -> Program {
        let p = plan(&m, &FusionOptions::default());
        generate(m, &p).unwrap()
    }

    /// `softmax(x)` over a fixed trailing axis: fully row-parallel.
    fn row_softmax_prog() -> Program {
        let mut b = Builder::new("rows");
        let s = b.dyn_dim("rows", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let y = b.softmax_last(x).unwrap();
        program_of(b.finish(vec![y]))
    }

    /// `softmax(x)` with rows *and* cols dynamic: the cols binding is the
    /// residual grouping key.
    fn two_sym_prog() -> Program {
        let mut b = Builder::new("rc");
        let s = b.dyn_dim("rows", 0, 0);
        let c = b.dyn_dim("cols", 0, 1);
        let x = b.param(DType::F32, vec![s, c]);
        let y = b.softmax_last(x).unwrap();
        program_of(b.finish(vec![y]))
    }

    fn transformer_prog() -> Program {
        let w = crate::workloads::transformer::workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let m = crate::passes::optimize(&m).unwrap();
        program_of(m)
    }

    #[test]
    fn analysis_accepts_row_parallel_programs() {
        let prog = row_softmax_prog();
        let a = analyze(&prog);
        assert!(a.eligible(), "row softmax must be batchable: {:?}", a.reason);
        assert!(a.stacked_steps > 0);
    }

    #[test]
    fn analysis_classifies_transformer_attention_per_request() {
        let prog = transformer_prog();
        let a = analyze(&prog);
        assert!(a.eligible(), "transformer must be batchable: {:?}", a.reason);
        assert!(a.stacked_steps > 0, "projections/FFN/layernorms must stack");
        // Attention mixes rows across the dynamic axis, so some launches
        // must stay per-request — if everything stacked, the analysis
        // would be unsound for `[heads, s, s]` scores.
        assert!(
            a.step_modes.iter().any(|&mo| mo == BatchMode::PerRequest),
            "attention core must run per request"
        );
    }

    #[test]
    fn analysis_rejects_static_leading_params_and_unique() {
        // TTS carries a `[1, MEL]` parameter: no shared leading symbol.
        let w = crate::workloads::tts::workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let a = analyze(&program_of(crate::passes::optimize(&m).unwrap()));
        assert!(!a.eligible());
        assert!(a.reason.is_some());

        // Unique's data-dependent extent is never batchable.
        let mut b = Builder::new("sparse");
        let n = b.dyn_dim("n", 0, 0);
        let ids = b.param(crate::dhlo::DType::I64, vec![n]);
        let u = b.unique(ids).unwrap();
        let a = analyze(&program_of(b.finish(vec![u])));
        assert_eq!(a.reason, Some("data-dependent extents (unique)"));
    }

    #[test]
    fn group_key_strips_the_batch_symbol() {
        let prog = two_sym_prog();
        let a = analyze(&prog);
        assert!(a.eligible(), "{:?}", a.reason);
        let m = &prog.module;
        let t = |rows: usize, cols: usize| {
            vec![Tensor::f32(&[rows, cols], vec![0.1; rows * cols])]
        };
        let k25 = group_key(m, &a, &t(2, 5)).unwrap();
        let k35 = group_key(m, &a, &t(3, 5)).unwrap();
        let k26 = group_key(m, &a, &t(2, 6)).unwrap();
        assert_eq!(k25, k35, "leading extent must not split groups");
        assert_ne!(k25, k26, "residual bindings must split groups");
        // Unbindable inputs yield no key (the request serves solo).
        assert!(group_key(m, &a, &[]).is_none());
    }

    #[test]
    fn run_batch_bit_matches_solo_on_transformer() {
        let prog = transformer_prog();
        let mut batched = executor();
        let mut solo = executor();
        let mut rng = Prng::new(5);
        let requests: Vec<Vec<Tensor>> = [6usize, 9, 12]
            .iter()
            .map(|&s| crate::workloads::transformer::gen_inputs(s, &mut rng))
            .collect();

        let want: Vec<(Vec<Tensor>, u64)> = requests
            .iter()
            .map(|r| {
                let o = solo.run(&prog, r).unwrap();
                (o.outputs, o.metrics.total_kernels())
            })
            .collect();
        let solo_kernels: u64 = want.iter().map(|(_, k)| k).sum();

        let out = batched.run_batch(&prog, &requests).unwrap();
        assert_eq!(out.outputs.len(), 3);
        for (got, (expect, _)) in out.outputs.iter().zip(&want) {
            assert_eq!(got, expect, "batched outputs diverged from solo runs");
        }
        assert_eq!(out.metrics.batched_requests, 3);
        assert_eq!(out.metrics.batched_launches, 1);
        assert!(
            out.metrics.total_kernels() < solo_kernels,
            "batch must launch fewer kernels ({} vs {} solo)",
            out.metrics.total_kernels(),
            solo_kernels
        );
    }

    #[test]
    fn run_batch_falls_back_for_singletons_and_mismatched_bindings() {
        let prog = two_sym_prog();
        let mut exec = executor();
        let mut rng = Prng::new(9);
        let t = |rows: usize, cols: usize, rng: &mut Prng| {
            vec![Tensor::f32(&[rows, cols], rng.fill_f32(rows * cols, 1.0))]
        };

        // Singleton: plain solo run.
        let one = vec![t(3, 5, &mut rng)];
        let out = exec.run_batch(&prog, &one).unwrap();
        assert_eq!(out.metrics.batched_launches, 0);
        assert_eq!(out.outputs.len(), 1);

        // Residual mismatch (different cols): sequential solo fallback,
        // still correct per request.
        let reqs = vec![t(2, 5, &mut rng), t(2, 6, &mut rng)];
        let out = exec.run_batch(&prog, &reqs).unwrap();
        assert_eq!(out.metrics.batched_launches, 0, "mismatched bindings must not stack");
        assert_eq!(out.outputs[0][0].dims, vec![2, 5]);
        assert_eq!(out.outputs[1][0].dims, vec![2, 6]);
        let mut solo = executor();
        for (r, o) in reqs.iter().zip(&out.outputs) {
            assert_eq!(&solo.run(&prog, r).unwrap().outputs, o);
        }
    }

    #[test]
    fn batch_rides_the_bucket_a_solo_request_compiled() {
        // NextPow2: a solo request at 5 rows compiles the bucket-8 kernel;
        // a batch of three requests totalling 5 rows lands in the SAME
        // bucket — zero new compiles, shared key family (the batch-bucket
        // key property).
        let prog = row_softmax_prog();
        let mut exec = executor();
        let mut rng = Prng::new(11);
        let t = |rows: usize, rng: &mut Prng| {
            vec![Tensor::f32(&[rows, 8], rng.fill_f32(rows * 8, 1.0))]
        };
        exec.run(&prog, &t(5, &mut rng)).unwrap();
        let misses = exec.cache.stats.misses;
        assert!(misses > 0);

        let reqs = vec![t(1, &mut rng), t(2, &mut rng), t(2, &mut rng)];
        let out = exec.run_batch(&prog, &reqs).unwrap();
        assert_eq!(out.metrics.batched_launches, 1);
        assert_eq!(out.metrics.compile_events, 0, "batch must reuse the bucket-8 kernel");
        assert_eq!(exec.cache.stats.misses, misses);
        // And solo references stay bit-exact.
        let mut solo = executor();
        for (r, o) in reqs.iter().zip(&out.outputs) {
            assert_eq!(&solo.run(&prog, r).unwrap().outputs, o);
        }
    }

    #[test]
    fn repeat_batch_groups_replay_with_bit_identical_outputs() {
        let prog = transformer_prog();
        let mut exec = executor();
        let mut plain = executor_no_plans();
        let mut rng = Prng::new(31);
        let requests: Vec<Vec<Tensor>> = [6usize, 9, 12]
            .iter()
            .map(|&s| crate::workloads::transformer::gen_inputs(s, &mut rng))
            .collect();
        let want: Vec<Vec<Tensor>> =
            requests.iter().map(|r| plain.run(&prog, r).unwrap().outputs).collect();

        let first = exec.run_batch(&prog, &requests).unwrap();
        assert_eq!(first.metrics.batch_plan_misses, 1, "first sight of the shape records");
        assert_eq!(first.metrics.batch_plan_hits, 0);
        for (got, expect) in first.outputs.iter().zip(&want) {
            assert_eq!(got, expect, "recorded dispatch diverged from solo interpret runs");
        }

        // The same group shape again, with fresh request contents.
        let mut rng2 = Prng::new(77);
        let requests2: Vec<Vec<Tensor>> = [6usize, 9, 12]
            .iter()
            .map(|&s| crate::workloads::transformer::gen_inputs(s, &mut rng2))
            .collect();
        let want2: Vec<Vec<Tensor>> =
            requests2.iter().map(|r| plain.run(&prog, r).unwrap().outputs).collect();
        let second = exec.run_batch(&prog, &requests2).unwrap();
        assert_eq!(second.metrics.batch_plan_hits, 1, "repeat shape must replay");
        assert_eq!(second.metrics.batch_plan_misses, 0);
        assert_eq!(second.metrics.batched_launches, 1);
        for (got, expect) in second.outputs.iter().zip(&want2) {
            assert_eq!(got, expect, "replayed dispatch diverged from solo interpret runs");
        }
        assert!(
            second.metrics.batch_dev_resident_bytes > 0,
            "stacked steps must chain through device buffers on replay"
        );
        assert_eq!(exec.batch_analyses, 1, "the analysis is computed once, never re-derived");
        assert_eq!(exec.batch_plan_stats.hits, 1);
        assert_eq!(exec.batch_plan_stats.entries, 1);
    }

    #[test]
    fn permuted_same_shape_groups_share_one_plan() {
        // A [3, 2] arrival order must replay the plan a [2, 3] group
        // recorded (sorted-extent key), with outputs still matched to the
        // actual member order.
        let prog = row_softmax_prog();
        let mut exec = executor();
        let mut plain = executor_no_plans();
        let mut rng = Prng::new(41);
        let t = |rows: usize, rng: &mut Prng| {
            vec![Tensor::f32(&[rows, 8], rng.fill_f32(rows * 8, 1.0))]
        };
        let a = vec![t(2, &mut rng), t(3, &mut rng)];
        let b = vec![t(3, &mut rng), t(2, &mut rng)];
        let first = exec.run_batch(&prog, &a).unwrap();
        assert_eq!(first.metrics.batch_plan_misses, 1);
        let second = exec.run_batch(&prog, &b).unwrap();
        assert_eq!(second.metrics.batch_plan_hits, 1, "sorted-extent key must hit");
        assert_eq!(exec.batch_plan_stats.entries, 1, "one plan serves both orders");
        assert_eq!(second.outputs[0][0].dims, vec![3, 8]);
        assert_eq!(second.outputs[1][0].dims, vec![2, 8]);
        for (r, o) in b.iter().zip(&second.outputs) {
            assert_eq!(&plain.run(&prog, r).unwrap().outputs, o);
        }
    }

    #[test]
    fn batch_plans_respect_the_plan_cache_gate() {
        let prog = row_softmax_prog();
        let mut exec = Executor::new(
            Arc::new(Device::cpu().unwrap()),
            ExecOptions { plan_cache: false, ..Default::default() },
        );
        let mut rng = Prng::new(43);
        let t = |rows: usize, rng: &mut Prng| {
            vec![Tensor::f32(&[rows, 8], rng.fill_f32(rows * 8, 1.0))]
        };
        for _ in 0..3 {
            let reqs = vec![t(2, &mut rng), t(2, &mut rng)];
            let out = exec.run_batch(&prog, &reqs).unwrap();
            assert_eq!(out.metrics.batch_plan_hits, 0);
            assert_eq!(out.metrics.batch_plan_misses, 0);
            assert_eq!(out.metrics.batched_launches, 1, "interpret tier still stacks");
        }
        assert_eq!(exec.batch_plan_stats.entries, 0);
    }

    #[test]
    fn poisoned_batch_guard_falls_back_to_the_interpret_tier() {
        use crate::runtime::plan::ElemGuard;
        let prog = row_softmax_prog();
        let mut exec = executor();
        let mut rng = Prng::new(47);
        let t = |rows: usize, rng: &mut Prng| {
            vec![Tensor::f32(&[rows, 8], rng.fill_f32(rows * 8, 1.0))]
        };
        let reqs = vec![t(2, &mut rng), t(3, &mut rng)];
        exec.run_batch(&prog, &reqs).unwrap();
        assert_eq!(exec.batch_plans.len(), 1);

        // Poison the recorded plan with a guard no request can satisfy —
        // the replay gate must reject it and serve the group through the
        // batched interpret tier, bit-exactly.
        let (key, plan) = {
            let (k, p) = exec.batch_plans.iter().next().unwrap();
            (k.clone(), p.clone())
        };
        let mut poisoned = BatchPlan {
            steps: plan.steps.clone(),
            param_guards: HashMap::new(),
            host_guards: plan.host_guards.clone(),
            device_peak_bytes: plan.device_peak_bytes,
            memory: plan.memory.clone(),
            reserve: None,
        };
        poisoned.param_guards.insert(0, vec![ElemGuard { index: 0, expect: -1 }]);
        exec.batch_plans.insert(key, Arc::new(poisoned));

        let reqs2 = vec![t(2, &mut rng), t(3, &mut rng)];
        let out = exec.run_batch(&prog, &reqs2).unwrap();
        assert_eq!(out.metrics.batch_plan_guard_misses, 1);
        assert_eq!(out.metrics.batch_plan_hits, 0);
        assert_eq!(out.metrics.batched_launches, 1, "guard miss still stacks, interpreted");
        let mut plain = executor_no_plans();
        for (r, o) in reqs2.iter().zip(&out.outputs) {
            assert_eq!(&plain.run(&prog, r).unwrap().outputs, o);
        }
    }

    #[test]
    fn batch_plan_cache_is_bounded_fifo() {
        let prog = row_softmax_prog();
        let mut exec = executor();
        exec.max_plans = 1;
        let mut rng = Prng::new(53);
        let t = |rows: usize, rng: &mut Prng| {
            vec![Tensor::f32(&[rows, 8], rng.fill_f32(rows * 8, 1.0))]
        };
        exec.run_batch(&prog, &[t(2, &mut rng), t(2, &mut rng)]).unwrap();
        exec.run_batch(&prog, &[t(3, &mut rng), t(3, &mut rng)]).unwrap();
        assert_eq!(exec.batch_plan_stats.entries, 1, "FIFO bound holds");
        assert_eq!(exec.batch_plan_stats.misses, 2);
        // The surviving shape replays; the evicted one re-records.
        let out = exec.run_batch(&prog, &[t(3, &mut rng), t(3, &mut rng)]).unwrap();
        assert_eq!(out.metrics.batch_plan_hits, 1);
        let out = exec.run_batch(&prog, &[t(2, &mut rng), t(2, &mut rng)]).unwrap();
        assert_eq!(out.metrics.batch_plan_misses, 1);
    }

    #[test]
    fn group_shape_checks_residual_agreement() {
        let prog = two_sym_prog();
        let a = analyze(&prog);
        let m = &prog.module;
        let t = |rows: usize, cols: usize| {
            vec![Tensor::f32(&[rows, cols], vec![0.1; rows * cols])]
        };
        let ok = group_shape(m, &a, &[t(2, 5), t(3, 5)]).unwrap();
        assert_eq!(ok.extents, vec![2, 3]);
        assert_eq!(ok.offsets, vec![0, 2, 5]);
        let key_a = ok.plan_key(prog.id, 0);
        let flipped = group_shape(m, &a, &[t(3, 5), t(2, 5)]).unwrap();
        assert_eq!(flipped.plan_key(prog.id, 0), key_a, "plan key sorts extents");
        assert!(group_shape(m, &a, &[t(2, 5), t(2, 6)]).is_none(), "residual mismatch");
        assert!(group_shape(m, &a, &[t(2, 5), vec![]]).is_none(), "unbindable member");
    }

    #[test]
    fn batch_replay_oom_demotes_to_stacked_interpret_then_recovers() {
        use crate::runtime::faults::{FaultPlan, FaultSite};
        let faults = Arc::new(FaultPlan::parse("seed=21,oom=1000:1").unwrap());
        let prog = row_softmax_prog();
        let mut exec = Executor::new(
            Arc::new(Device::cpu_with_faults(Some(faults.clone())).unwrap()),
            ExecOptions::default(),
        );
        let mut plain = executor_no_plans();
        let mut rng = Prng::new(59);
        let t = |rows: usize, rng: &mut Prng| {
            vec![Tensor::f32(&[rows, 8], rng.fill_f32(rows * 8, 1.0))]
        };

        // Record the plan (no replay, so the armed OOM stays dormant).
        let first = exec.run_batch(&prog, &[t(2, &mut rng), t(3, &mut rng)]).unwrap();
        assert_eq!(first.metrics.batch_plan_misses, 1);
        assert_eq!(first.metrics.demotions, 0);

        // Replay hits the injected OOM: the group demotes to the batched
        // interpret tier, outputs stay bit-exact, and the failed replay's
        // arena accounting unwinds.
        let reqs2 = vec![t(2, &mut rng), t(3, &mut rng)];
        let out = exec.run_batch(&prog, &reqs2).unwrap();
        assert_eq!(out.metrics.demotions, 1);
        assert_eq!(out.metrics.batch_plan_hits, 0);
        assert_eq!(out.metrics.batched_launches, 1, "demotion still stacks, interpreted");
        assert_eq!(exec.pool.device.resident_bytes(), 0, "failed replay must unwind the arena");
        for (r, o) in reqs2.iter().zip(&out.outputs) {
            assert_eq!(&plain.run(&prog, r).unwrap().outputs, o);
        }
        assert_eq!(faults.fired(FaultSite::DeviceOom), 1);

        // Fault exhausted: the installed plan replays clean.
        let out = exec.run_batch(&prog, &[t(2, &mut rng), t(3, &mut rng)]).unwrap();
        assert_eq!(out.metrics.batch_plan_hits, 1);
        assert_eq!(out.metrics.demotions, 0);
    }

    #[test]
    fn batched_outputs_split_at_request_boundaries() {
        let prog = row_softmax_prog();
        let mut exec = executor();
        let mut rng = Prng::new(13);
        let reqs: Vec<Vec<Tensor>> = [3usize, 1, 4]
            .iter()
            .map(|&r| vec![Tensor::f32(&[r, 8], rng.fill_f32(r * 8, 1.0))])
            .collect();
        let out = exec.run_batch(&prog, &reqs).unwrap();
        for (req, outs) in reqs.iter().zip(&out.outputs) {
            assert_eq!(outs[0].dims, req[0].dims, "per-request extents restored");
        }
        assert!(out.metrics.batch_stack_bytes > 0, "stacking traffic is accounted");
    }
}
