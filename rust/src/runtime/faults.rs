//! Deterministic fault injection (the chaos-testing substrate).
//!
//! Serving robustness claims are only as good as the failures they were
//! proven against. A [`FaultPlan`] is a seeded, countable schedule of
//! failures injected at the runtime's existing seams:
//!
//! - **`compile`** — kernel compilation returns an error
//!   (`Device::compile_hlo_*`, surfaced through the `KernelStore`'s
//!   single-flight machinery to every joined waiter);
//! - **`compile-panic`** — a compile-pool thread panics mid-compile (the
//!   store's drop guard must fail the flight instead of wedging it in
//!   `Pending` forever);
//! - **`h2d` / `d2h`** — host↔device transfers fail (`Device::h2d`/`d2h`),
//!   demoting replays back down the execution ladder;
//! - **`oom`** — device allocation fails (simulated OOM at the
//!   `DeviceArena` acquire inside the device-resident replay tiers);
//! - **`panic`** — a coordinator worker panics while serving a request
//!   (exercises supervision: requeue + worker respawn).
//!
//! Firing is deterministic: each site keeps an atomic call counter, and call
//! `n` fires iff `splitmix64(seed ^ site ^ n) % 1000 < rate` (rates are
//! per-mille), subject to the site's optional fire limit. Two plans built
//! from the same spec fire at identical call indices, so chaos tests
//! reproduce bit-for-bit; the `fired`/`calls` accessors let tests assert a
//! fault actually happened rather than trusting the schedule.
//!
//! Specs look like `"seed=7,compile=200,h2d=100,oom=150:2,panic=1000:1"`:
//! per-site per-mille rates with an optional `:limit` cap on total fires.
//! Plans are wired explicitly — `Device` captures `DISC_FAULTS` at
//! construction ([`FaultPlan::from_env`]), and `ServeOptions::faults` /
//! `DiscCompiler::with_faults` thread an explicit plan — so fault-free
//! paths carry a `None` and pay a single branch.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

/// Environment variable holding the process-wide fault spec.
pub const ENV_VAR: &str = "DISC_FAULTS";

/// Where a fault fires. Each site maps to one seam in the runtime.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Kernel compilation returns an error.
    Compile,
    /// A compile-pool thread panics mid-compile.
    CompilePanic,
    /// A host-to-device transfer fails.
    H2d,
    /// A device-to-host transfer fails.
    D2h,
    /// Device allocation fails (simulated OOM).
    DeviceOom,
    /// A coordinator worker panics while serving a request.
    WorkerPanic,
}

/// All sites, in spec-key order.
pub const SITES: [FaultSite; 6] = [
    FaultSite::Compile,
    FaultSite::CompilePanic,
    FaultSite::H2d,
    FaultSite::D2h,
    FaultSite::DeviceOom,
    FaultSite::WorkerPanic,
];

impl FaultSite {
    fn index(self) -> usize {
        match self {
            FaultSite::Compile => 0,
            FaultSite::CompilePanic => 1,
            FaultSite::H2d => 2,
            FaultSite::D2h => 3,
            FaultSite::DeviceOom => 4,
            FaultSite::WorkerPanic => 5,
        }
    }

    /// The spec key (and the tag used in injected error messages).
    pub fn key(self) -> &'static str {
        match self {
            FaultSite::Compile => "compile",
            FaultSite::CompilePanic => "compile-panic",
            FaultSite::H2d => "h2d",
            FaultSite::D2h => "d2h",
            FaultSite::DeviceOom => "oom",
            FaultSite::WorkerPanic => "panic",
        }
    }

    /// Per-site hash salt so sites with equal rates fire independently.
    fn salt(self) -> u64 {
        // Arbitrary distinct odd constants.
        [
            0x9e37_79b9_7f4a_7c15,
            0xbf58_476d_1ce4_e5b9,
            0x94d0_49bb_1331_11eb,
            0xd6e8_feb8_6659_fd93,
            0xa076_1d64_78bd_642f,
            0xe703_7ed1_a0b4_28db,
        ][self.index()]
    }
}

#[derive(Debug, Default)]
struct SiteState {
    /// Firing probability in per-mille (0 = site disabled).
    rate_permille: u64,
    /// Max total fires (`u64::MAX` = unlimited).
    limit: u64,
    /// Times this site was consulted.
    calls: AtomicU64,
    /// Times this site actually fired.
    fired: AtomicU64,
}

/// A seeded, countable fault-injection schedule. See the module docs.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    sites: [SiteState; 6],
}

/// The SplitMix64 mixing function behind every fault decision. Public so
/// seeded test harnesses (e.g. the differential property suite) can derive
/// reproducible per-case seeds from the same primitive without pulling in
/// an external PRNG crate.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl FaultPlan {
    /// Parse a spec like `"seed=7,compile=200,h2d=100,oom=150:2"`.
    ///
    /// Each comma-separated entry is `site=rate[:limit]` with `rate` in
    /// per-mille (0–1000) and `limit` an optional cap on total fires;
    /// `seed=N` seeds the hash. Unknown keys are an error so typos cannot
    /// silently disable a chaos matrix.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan { seed: 0, sites: Default::default() };
        for entry in spec.split(',') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (key, value) = entry
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("fault spec entry {entry:?}: expected key=value"))?;
            if key == "seed" {
                plan.seed = value.parse().map_err(|_| {
                    anyhow::anyhow!("fault spec entry {entry:?}: seed must be an integer")
                })?;
                continue;
            }
            let Some(site) = SITES.iter().copied().find(|s| s.key() == key) else {
                bail!("fault spec entry {entry:?}: unknown site {key:?}");
            };
            let (rate, limit) = match value.split_once(':') {
                Some((r, l)) => (r, Some(l)),
                None => (value, None),
            };
            let rate: u64 = rate.parse().map_err(|_| {
                anyhow::anyhow!("fault spec entry {entry:?}: rate must be an integer")
            })?;
            if rate > 1000 {
                bail!("fault spec entry {entry:?}: rate is per-mille (0-1000)");
            }
            let limit: u64 = match limit {
                Some(l) => l.parse().map_err(|_| {
                    anyhow::anyhow!("fault spec entry {entry:?}: limit must be an integer")
                })?,
                None => u64::MAX,
            };
            let s = &mut plan.sites[site.index()];
            s.rate_permille = rate;
            s.limit = limit;
        }
        Ok(plan)
    }

    /// Build a plan from `DISC_FAULTS`, or `None` when the variable is
    /// unset/empty. A malformed spec is reported on stderr and ignored
    /// rather than silently dropping the whole serving process.
    pub fn from_env() -> Option<Arc<FaultPlan>> {
        let spec = std::env::var(ENV_VAR).ok()?;
        let spec = spec.trim();
        if spec.is_empty() {
            return None;
        }
        match FaultPlan::parse(spec) {
            Ok(p) => Some(Arc::new(p)),
            Err(e) => {
                eprintln!("warning: ignoring {ENV_VAR}={spec:?}: {e}");
                None
            }
        }
    }

    /// The seed this plan hashes call indices with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Consult `site`: advance its call counter and decide (deterministically
    /// in the counter value) whether this call fails.
    pub fn should_fail(&self, site: FaultSite) -> bool {
        let s = &self.sites[site.index()];
        if s.rate_permille == 0 {
            return false;
        }
        let n = s.calls.fetch_add(1, Ordering::Relaxed);
        if splitmix64(self.seed ^ site.salt() ^ n) % 1000 >= s.rate_permille {
            return false;
        }
        // Respect the fire limit without ever overshooting it.
        loop {
            let f = s.fired.load(Ordering::Relaxed);
            if f >= s.limit {
                return false;
            }
            if s.fired.compare_exchange(f, f + 1, Ordering::Relaxed, Ordering::Relaxed).is_ok() {
                return true;
            }
        }
    }

    /// Times `site` was consulted so far.
    pub fn calls(&self, site: FaultSite) -> u64 {
        self.sites[site.index()].calls.load(Ordering::Relaxed)
    }

    /// Times `site` actually fired so far.
    pub fn fired(&self, site: FaultSite) -> u64 {
        self.sites[site.index()].fired.load(Ordering::Relaxed)
    }

    /// Total fires across every site.
    pub fn total_fired(&self) -> u64 {
        SITES.iter().map(|&s| self.fired(s)).sum()
    }

    /// True if `site` has a non-zero rate configured.
    pub fn arms(&self, site: FaultSite) -> bool {
        self.sites[site.index()].rate_permille > 0
    }
}

/// Consult an optional plan at `site`; on a fire, return an injected error
/// tagged with the site key and `what` (the seam's own description).
pub fn check(plan: Option<&FaultPlan>, site: FaultSite, what: &str) -> Result<()> {
    if let Some(p) = plan {
        if p.should_fail(site) {
            bail!("injected {} fault ({what})", site.key());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_rates_limits_and_seed() {
        let p = FaultPlan::parse("seed=7,compile=200,h2d=100,oom=150:2,panic=1000:1").unwrap();
        assert_eq!(p.seed(), 7);
        assert!(p.arms(FaultSite::Compile));
        assert!(p.arms(FaultSite::H2d));
        assert!(!p.arms(FaultSite::D2h));
        assert_eq!(p.sites[FaultSite::DeviceOom.index()].limit, 2);
        assert_eq!(p.sites[FaultSite::WorkerPanic.index()].limit, 1);
        assert_eq!(p.sites[FaultSite::Compile.index()].limit, u64::MAX);
    }

    #[test]
    fn parse_rejects_unknown_sites_and_bad_rates() {
        assert!(FaultPlan::parse("seed=1,compiel=100").is_err());
        assert!(FaultPlan::parse("compile=1500").is_err());
        assert!(FaultPlan::parse("compile").is_err());
        assert!(FaultPlan::parse("compile=abc").is_err());
    }

    #[test]
    fn firing_is_deterministic_in_the_call_index() {
        let a = FaultPlan::parse("seed=42,h2d=300").unwrap();
        let b = FaultPlan::parse("seed=42,h2d=300").unwrap();
        let fa: Vec<bool> = (0..200).map(|_| a.should_fail(FaultSite::H2d)).collect();
        let fb: Vec<bool> = (0..200).map(|_| b.should_fail(FaultSite::H2d)).collect();
        assert_eq!(fa, fb);
        assert!(fa.iter().any(|&x| x), "rate 300/1000 over 200 calls must fire");
        assert!(fa.iter().any(|&x| !x), "rate 300/1000 must not always fire");
        assert_eq!(a.calls(FaultSite::H2d), 200);
        assert_eq!(a.fired(FaultSite::H2d), fa.iter().filter(|&&x| x).count() as u64);
    }

    #[test]
    fn different_seeds_give_different_schedules() {
        let a = FaultPlan::parse("seed=1,d2h=500").unwrap();
        let b = FaultPlan::parse("seed=2,d2h=500").unwrap();
        let fa: Vec<bool> = (0..64).map(|_| a.should_fail(FaultSite::D2h)).collect();
        let fb: Vec<bool> = (0..64).map(|_| b.should_fail(FaultSite::D2h)).collect();
        assert_ne!(fa, fb);
    }

    #[test]
    fn limit_caps_total_fires() {
        let p = FaultPlan::parse("seed=3,oom=1000:2").unwrap();
        let fired = (0..50).filter(|_| p.should_fail(FaultSite::DeviceOom)).count();
        assert_eq!(fired, 2);
        assert_eq!(p.fired(FaultSite::DeviceOom), 2);
        assert_eq!(p.calls(FaultSite::DeviceOom), 50);
        assert_eq!(p.total_fired(), 2);
    }

    #[test]
    fn disabled_sites_never_fire_and_check_tags_errors() {
        let p = FaultPlan::parse("seed=9,compile=1000:1").unwrap();
        assert!(!p.should_fail(FaultSite::WorkerPanic));
        let e = check(Some(&p), FaultSite::Compile, "hlo build").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("injected compile fault"), "{msg}");
        assert!(msg.contains("hlo build"), "{msg}");
        assert!(check(Some(&p), FaultSite::Compile, "hlo build").is_ok(), "limit exhausted");
        assert!(check(None, FaultSite::Compile, "x").is_ok());
    }
}
