//! Compile-time generated runtime flow (§4.2).
//!
//! This is DISC's central architectural claim versus Nimble: instead of a
//! VM that *interprets* the graph at runtime (walking nodes, re-deriving
//! shapes, refcounting buffers per visit — see [`crate::vm`]), DISC
//! generates the whole runtime flow at compile time as a flat instruction
//! sequence: shape calculation, buffer `Alloc`/`Dealloc` placement from
//! liveness analysis, kernel launches with precomputed signatures, library
//! calls, and host ops. The executor then just walks the array — no graph,
//! no per-node decisions.

use crate::dhlo::{Module, Op, ValueId};
use crate::fusion::signature::signature;
use crate::fusion::{host_shape_values, FusionGroup, FusionPlan};
use crate::shape::SymId;
use anyhow::Result;

/// One step of the generated flow.
#[derive(Debug, Clone)]
pub enum Step {
    /// Evaluate a host-side op (shape math, `GetDimSize`, s64 index
    /// arithmetic feeding dynamic-twin operands).
    EvalHost { value: ValueId },
    /// Zero-cost reshape (metadata-only).
    Bitcast { value: ValueId },
    /// Launch the `idx`-th fused kernel.
    LaunchFused { idx: usize },
    /// Launch a singleton memory-intensive kernel (pre-built op kernel).
    LaunchOp { value: ValueId },
    /// Compute-intensive library call (§4.5).
    LibraryCall { value: ValueId },
    /// Release a dead buffer (placed by liveness analysis).
    Dealloc { value: ValueId },
}

/// Launch metadata for one fusion group, precomputed at compile time so the
/// hot path does no signature or symbol discovery.
#[derive(Debug, Clone)]
pub struct FusedLaunch {
    pub group: FusionGroup,
    /// Shape-agnostic cache signature.
    pub sig: String,
    /// Canonical dynamic symbols, in bucket-key order.
    pub syms: Vec<SymId>,
    /// External tensor inputs in kernel-parameter order (this group's own
    /// value ids — the cached KernelSpec may belong to a different group
    /// with the same signature).
    pub inputs: Vec<ValueId>,
    pub root: ValueId,
}

/// A compiled program: the module (for metadata), the flat step sequence,
/// and per-group launch info.
#[derive(Debug, Clone)]
pub struct Program {
    /// Process-unique identity, assigned at generation time. Launch-plan
    /// caches key on `(id, symbol bindings)`; clones share the id (and may
    /// therefore share plans — the steps are identical).
    pub id: u64,
    pub module: Module,
    pub steps: Vec<Step>,
    pub fused: Vec<FusedLaunch>,
    /// Which values are host-side.
    pub host: Vec<bool>,
}

impl Program {
    /// Number of device-kernel launch steps (for plan-level assertions).
    pub fn launch_steps(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| matches!(s, Step::LaunchFused { .. } | Step::LaunchOp { .. }))
            .count()
    }
}

/// Generate the runtime flow for a module under a fusion plan.
pub fn generate(module: Module, plan: &FusionPlan) -> Result<Program> {
    let m = &module;
    let n = m.instrs.len();
    let host = host_shape_values(m);

    let mut fused: Vec<FusedLaunch> = Vec::with_capacity(plan.groups.len());
    for g in &plan.groups {
        fused.push(FusedLaunch {
            group: g.clone(),
            sig: signature(m, g),
            syms: crate::codegen::hlo::group_syms(m, g),
            inputs: crate::fusion::signature::external_inputs(m, g)
                .into_iter()
                .map(|e| e.value)
                .collect(),
            root: g.root,
        });
    }

    // Emit compute steps in instruction order; a fused group is launched at
    // its root's position (all members dominate the root).
    let mut steps: Vec<Step> = Vec::with_capacity(n);
    for (id, ins) in m.instrs.iter().enumerate() {
        match &ins.op {
            Op::Param { .. } | Op::Const { .. } => {}
            _ if host[id] => steps.push(Step::EvalHost { value: id }),
            Op::Reshape | Op::DReshape => steps.push(Step::Bitcast { value: id }),
            Op::Dot => steps.push(Step::LibraryCall { value: id }),
            _ => match plan.membership[id] {
                Some(gid) if plan.groups[gid].root == id => {
                    let idx = fused.iter().position(|f| f.group.id == gid).unwrap();
                    steps.push(Step::LaunchFused { idx });
                }
                Some(_) => {} // interior member: computed inside the kernel
                None => steps.push(Step::LaunchOp { value: id }),
            },
        }
    }

    // Liveness: values read by each step.
    let reads_of = |s: &Step| -> Vec<ValueId> {
        match s {
            Step::EvalHost { value }
            | Step::Bitcast { value }
            | Step::LaunchOp { value }
            | Step::LibraryCall { value } => m.instrs[*value].operands.clone(),
            Step::LaunchFused { idx } => {
                let fl = &fused[*idx];
                let mut r: Vec<ValueId> =
                    crate::fusion::signature::external_inputs(m, &fl.group)
                        .into_iter()
                        .map(|e| e.value)
                        .collect();
                // Symbol definitions may read host tensors (Elem exprs).
                for s in &fl.syms {
                    let mut vdeps = Vec::new();
                    m.syms.def(*s).value_deps(&mut vdeps);
                    r.extend(vdeps);
                }
                r
            }
            Step::Dealloc { .. } => vec![],
        }
    };

    let mut last_use: Vec<Option<usize>> = vec![None; n];
    for (si, s) in steps.iter().enumerate() {
        for v in reads_of(s) {
            last_use[v] = Some(si);
        }
    }
    // Module outputs live forever; so do values nothing ever reads but that
    // a step produces (deallocated right after production below).
    let mut keep = vec![false; n];
    for &o in &m.outputs {
        keep[o] = true;
    }

    // Insert Dealloc steps after each step index. Build the final sequence.
    let mut out_steps: Vec<Step> = Vec::with_capacity(steps.len() * 2);
    for (si, s) in steps.iter().enumerate() {
        out_steps.push(s.clone());
        for v in 0..n {
            if keep[v] || matches!(m.instrs[v].op, Op::Const { .. }) {
                continue;
            }
            if last_use[v] == Some(si) {
                out_steps.push(Step::Dealloc { value: v });
            }
        }
    }

    static PROGRAM_IDS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let id = PROGRAM_IDS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    Ok(Program { id, module, steps: out_steps, fused, host })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::fusion::{plan, FusionOptions};
    use crate::shape::Dim;

    #[test]
    fn program_structure_for_mlp_block() {
        let mut b = Builder::new("mlp");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let w = b.param(DType::F32, vec![Dim::Fixed(8), Dim::Fixed(8)]);
        let h = b.dot(x, w).unwrap();
        let r = b.unary(UnKind::Relu, h);
        let o = b.add(r, x).unwrap();
        let m = b.finish(vec![o]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();

        let lib = prog.steps.iter().filter(|s| matches!(s, Step::LibraryCall { .. })).count();
        let fused = prog.steps.iter().filter(|s| matches!(s, Step::LaunchFused { .. })).count();
        assert_eq!(lib, 1, "one GEMM library call");
        assert_eq!(fused, 1, "relu+add fuse into one kernel");
        // The GEMM result h dies after the fused kernel consumes it.
        assert!(prog
            .steps
            .iter()
            .any(|s| matches!(s, Step::Dealloc { value } if *value == 2)));
    }

    #[test]
    fn outputs_never_deallocated() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let y = b.unary(UnKind::Tanh, x);
        let m = b.finish(vec![y]);
        let p = plan(&m, &FusionOptions::default());
        let y_id = y;
        let prog = generate(m, &p).unwrap();
        assert!(!prog
            .steps
            .iter()
            .any(|s| matches!(s, Step::Dealloc { value } if *value == y_id)));
    }

    #[test]
    fn dealloc_placed_immediately_after_last_use() {
        // x -> tanh (fused alone) -> exp (fused alone? no — they chain into
        // one group). Use a dot to split: tanh feeds dot and dies after it.
        let mut b = Builder::new("t");
        let x = b.param(DType::F32, vec![Dim::Fixed(4), Dim::Fixed(4)]);
        let t = b.unary(UnKind::Tanh, x);
        let d = b.dot(t, t).unwrap();
        let m = b.finish(vec![d]);
        let p = plan(&m, &FusionOptions::default());
        let prog = generate(m, &p).unwrap();
        // Expect: LaunchFused(tanh), LibraryCall(dot), Dealloc(t)...
        let pos_lib = prog
            .steps
            .iter()
            .position(|s| matches!(s, Step::LibraryCall { .. }))
            .unwrap();
        let pos_dealloc_t = prog
            .steps
            .iter()
            .position(|s| matches!(s, Step::Dealloc { value } if *value == 1))
            .unwrap();
        assert_eq!(pos_dealloc_t, pos_lib + 1, "free-as-soon-as-dead placement");
    }

    #[test]
    fn host_ops_scheduled_on_host() {
        let mut b = Builder::new("h");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let st = b.i64_vec(&[0]);
        let li = b.i64_vec(&[2]);
        let sr = b.i64_vec(&[1]);
        let li2 = b.add(li, sr).unwrap();
        let sl = b.dslice(x, st, li2, sr).unwrap();
        let m = b.finish(vec![sl]);
        let p = plan(&m, &FusionOptions::default());
        let li2_id = li2;
        let prog = generate(m, &p).unwrap();
        assert!(prog
            .steps
            .iter()
            .any(|s| matches!(s, Step::EvalHost { value } if *value == li2_id)));
        // The dslice itself is a device-side singleton kernel.
        assert!(prog
            .steps
            .iter()
            .any(|s| matches!(s, Step::LaunchOp { .. })));
    }
}
