//! Shape-agnostic fusion-pattern signatures — the cache key that lets DISC
//! compile a fusion *once* and reuse it for every shape (§2: "we do not need
//! to consider shape information to check whether two fusion patterns are
//! the same for code generation").
//!
//! The signature canonicalizes a fusion group: members are relabelled in
//! topological order, external inputs become numbered slots typed only by
//! `(dtype, rank, dynamic-axis bitmask)`, and op attributes that are *not*
//! shape values (permutations, reduce axes, broadcast mappings) are kept.
//! Concrete extents never appear, so `f32[17,768]` and `f32[512,768]`
//! produce the same signature.

use crate::dhlo::{Module, Op, ValueId};
use crate::fusion::FusionGroup;
use std::collections::HashMap;
use std::fmt::Write as _;

/// External input to a fusion group: a value produced outside the group.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExternalInput {
    pub value: ValueId,
    /// Which axes of this input are dynamic (per canonical symbol identity
    /// *within the group*, so shared dims keep their sharing).
    pub dyn_axes: Vec<bool>,
}

/// Enumerate the group's external inputs in first-use order.
pub fn external_inputs(m: &Module, g: &FusionGroup) -> Vec<ExternalInput> {
    let mut seen = HashMap::new();
    let mut out = Vec::new();
    for &v in &g.members {
        for &o in &m.instrs[v].operands {
            if !g.contains(o) && !seen.contains_key(&o) {
                seen.insert(o, out.len());
                let dyn_axes = m
                    .ty(o)
                    .dims
                    .iter()
                    .map(|&d| m.syms.canon_dim(d).is_dynamic())
                    .collect();
                out.push(ExternalInput { value: o, dyn_axes });
            }
        }
    }
    out
}

/// Compute the shape-agnostic signature string for a fusion group.
///
/// Two groups with the same signature generate identical kernel code modulo
/// the bucketed extents, so they share a compiled-executable cache entry per
/// bucket (the paper's "no recompilation for new shapes" property).
pub fn signature(m: &Module, g: &FusionGroup) -> String {
    let externals = external_inputs(m, g);
    let ext_index: HashMap<ValueId, usize> =
        externals.iter().enumerate().map(|(i, e)| (e.value, i)).collect();
    let member_index: HashMap<ValueId, usize> =
        g.members.iter().enumerate().map(|(i, &v)| (v, i)).collect();

    // Local symbol numbering: canonical symbols in first-appearance order
    // across external inputs and member types. This keeps *sharing*
    // information (same dynamic extent reused) without leaking values.
    let mut sym_ids: HashMap<crate::shape::SymId, usize> = HashMap::new();
    let mut dim_str = |m: &Module, d: crate::shape::Dim| -> String {
        match m.syms.canon_dim(d) {
            crate::shape::Dim::Fixed(n) => n.to_string(),
            crate::shape::Dim::Sym(s) => {
                let next = sym_ids.len();
                let k = *sym_ids.entry(s).or_insert(next);
                format!("d{k}")
            }
        }
    };

    let mut out = String::new();
    let _ = write!(out, "kind={:?};", g.kind);
    for (i, e) in externals.iter().enumerate() {
        let t = m.ty(e.value);
        let dims: Vec<String> = t.dims.iter().map(|&d| dim_str(m, d)).collect();
        let _ = write!(out, "e{i}:{}[{}];", t.dtype, dims.join(","));
    }
    for &v in &g.members {
        let ins = &m.instrs[v];
        let ops: Vec<String> = ins
            .operands
            .iter()
            .map(|o| {
                if let Some(&k) = member_index.get(o) {
                    format!("m{k}")
                } else {
                    format!("e{}", ext_index[o])
                }
            })
            .collect();
        let dims: Vec<String> = ins.ty.dims.iter().map(|&d| dim_str(m, d)).collect();
        let _ = write!(
            out,
            "m{}={}({})[{}]{};",
            member_index[&v],
            ins.op.name(),
            ops.join(","),
            dims.join(","),
            attr_sig(&ins.op)
        );
    }
    let _ = write!(out, "root=m{}", member_index[&g.root]);
    out
}

fn attr_sig(op: &Op) -> String {
    match op {
        Op::Broadcast { dims } | Op::DBroadcast { dims } => format!("{{bd={dims:?}}}"),
        Op::Transpose { perm } => format!("{{p={perm:?}}}"),
        Op::Concat { axis } => format!("{{a={axis}}}"),
        Op::Reduce { axes, .. } => format!("{{ax={axes:?}}}"),
        Op::Gather { axis } => format!("{{a={axis}}}"),
        Op::Iota { axis } => format!("{{a={axis}}}"),
        // Static slice/pad attrs ARE shape values; including them would make
        // the signature shape-dependent. Static-shaped ops only reach fused
        // codegen through the static pipeline, which keys by shape anyway.
        Op::Slice { starts, limits, strides } => format!("{{s={starts:?},{limits:?},{strides:?}}}"),
        Op::Pad { low, high } => format!("{{p={low:?},{high:?}}}"),
        _ => String::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, Module, UnKind};
    use crate::fusion::{plan, FusionOptions};
    use crate::shape::Dim;

    /// Build the same pattern twice with different static hints to verify
    /// shape-agnosticism over *dynamic* dims.
    fn chain_module(hidden: usize) -> Module {
        let mut b = Builder::new("sig");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(hidden)]);
        let t = b.unary(UnKind::Tanh, x);
        let y = b.add(x, t).unwrap();
        b.finish(vec![y])
    }

    #[test]
    fn same_pattern_same_signature() {
        let m1 = chain_module(64);
        let m2 = chain_module(64);
        let p1 = plan(&m1, &FusionOptions::default());
        let p2 = plan(&m2, &FusionOptions::default());
        assert_eq!(signature(&m1, &p1.groups[0]), signature(&m2, &p2.groups[0]));
    }

    #[test]
    fn different_static_dim_different_signature() {
        // The static hidden size is part of codegen, so it differs.
        let m1 = chain_module(64);
        let m2 = chain_module(128);
        let p1 = plan(&m1, &FusionOptions::default());
        let p2 = plan(&m2, &FusionOptions::default());
        assert_ne!(signature(&m1, &p1.groups[0]), signature(&m2, &p2.groups[0]));
    }

    #[test]
    fn dynamic_dims_are_anonymous() {
        let m = chain_module(64);
        let p = plan(&m, &FusionOptions::default());
        let sig = signature(&m, &p.groups[0]);
        assert!(sig.contains("d0"), "dynamic dims appear as local ids: {sig}");
        assert!(!sig.contains("s0"), "raw symbol names must not leak: {sig}");
    }

    #[test]
    fn different_ops_different_signature() {
        let mut b = Builder::new("a");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let t = b.unary(UnKind::Tanh, x);
        let m1 = b.finish(vec![t]);

        let mut b2 = Builder::new("b");
        let s2 = b2.dyn_dim("n", 0, 0);
        let x2 = b2.param(DType::F32, vec![s2]);
        let t2 = b2.unary(UnKind::Exp, x2);
        let m2 = b2.finish(vec![t2]);

        let p1 = plan(&m1, &FusionOptions::default());
        let p2 = plan(&m2, &FusionOptions::default());
        assert_ne!(signature(&m1, &p1.groups[0]), signature(&m2, &p2.groups[0]));
    }

    #[test]
    fn external_inputs_in_first_use_order() {
        let mut b = Builder::new("x");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let y = b.param(DType::F32, vec![Dim::Fixed(1)]);
        let ybc = b.broadcast(y, vec![s], vec![0]).unwrap();
        let z = b.add(x, ybc).unwrap();
        let m = b.finish(vec![z]);
        let p = plan(&m, &FusionOptions::default());
        let g = p.groups.iter().find(|g| g.contains(z)).unwrap();
        let ext = external_inputs(&m, g);
        assert_eq!(ext.len(), 2);
        assert!(ext[0].dyn_axes[0] || ext[1].dyn_axes[0]);
    }
}
