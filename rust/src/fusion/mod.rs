//! Kernel fusion planning without full shape information (§4.3).
//!
//! The planner clusters memory-intensive ops into fusion groups using two
//! *shape hints*, mirroring the paper:
//!
//! 1. **Shape propagation** — structural equality of symbolic dim vectors
//!    between producers and consumers (the per-op propagation table lives in
//!    [`crate::dhlo::op::Op::prop_class`]).
//! 2. **Shape constraints** — the dimension-equality (union-find closure)
//!    and tensor-size-equality classes collected at lowering time (§4.2.1).
//!    These widen the fusion scope beyond what pure propagation can prove;
//!    [`FusionOptions::use_constraints`] toggles them for the ablation bench.
//!
//! Two templates are used, as in the paper: classic **loop fusion** with an
//! elementwise root, and **input fusion** with a reduce root. Compute
//! intensive ops (`Dot`) never fuse — they go through the library (§4.5).

pub mod signature;

use crate::dhlo::{Module, Op, ValueId};
use std::collections::HashMap;

/// Fusion template kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GroupKind {
    /// Elementwise root; every member shares the root's iteration domain.
    Loop,
    /// Reduce root; producers share the reduce *input* domain.
    Input,
}

/// One fusion group: a connected set of instructions compiled into a single
/// kernel whose only escaping value is `root`.
#[derive(Debug, Clone)]
pub struct FusionGroup {
    pub id: usize,
    pub kind: GroupKind,
    /// Members in topological (ascending id) order; the root is last.
    pub members: Vec<ValueId>,
    pub root: ValueId,
}

impl FusionGroup {
    pub fn len(&self) -> usize {
        self.members.len()
    }
    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
    pub fn contains(&self, v: ValueId) -> bool {
        self.members.contains(&v)
    }
}

/// Planner options (ablation knobs).
#[derive(Debug, Clone)]
pub struct FusionOptions {
    /// Use collected shape constraints (union-find closure + size classes)
    /// in addition to structural propagation. Paper default: on.
    pub use_constraints: bool,
    /// Allow reduce-rooted input fusion. Paper default: on.
    pub enable_input_fusion: bool,
    /// Upper bound on members per group (guards pathological graphs).
    pub max_group_size: usize,
    /// Disable fusion entirely (framework-eager comparison).
    pub enabled: bool,
}

impl Default for FusionOptions {
    fn default() -> Self {
        FusionOptions {
            use_constraints: true,
            enable_input_fusion: true,
            max_group_size: 64,
            enabled: true,
        }
    }
}

/// The fusion plan over a module.
#[derive(Debug, Clone)]
pub struct FusionPlan {
    pub groups: Vec<FusionGroup>,
    /// instr id → group index (None for non-fused ops: params, constants,
    /// compute-intensive ops, host shape ops, …).
    pub membership: Vec<Option<usize>>,
}

impl FusionPlan {
    /// Device-kernel launch count implied by the plan: one per group plus
    /// one per unfused memory-intensive tensor op.
    pub fn kernel_count(&self, m: &Module) -> usize {
        let fused: usize = self.groups.len();
        let unfused = m
            .instrs
            .iter()
            .enumerate()
            .filter(|(id, ins)| {
                self.membership[*id].is_none()
                    && !matches!(ins.op, Op::Param { .. } | Op::Const { .. })
                    && !ins.op.is_compute_intensive()
            })
            .count();
        fused + unfused
    }

    pub fn group_of(&self, v: ValueId) -> Option<&FusionGroup> {
        self.membership[v].map(|g| &self.groups[g])
    }
}

/// Shape-compatibility between a candidate and a group's iteration domain.
fn compatible(m: &Module, cand: ValueId, domain: ValueId, opts: &FusionOptions) -> bool {
    let (tc, td) = (m.ty(cand), m.ty(domain));
    if tc.dims.len() == td.dims.len() && tc.dims == td.dims {
        // Structural (propagation) equality — identical symbols/extents.
        return true;
    }
    if opts.use_constraints {
        // Constraint closure: canonicalized dim equality, or recorded
        // tensor-size equality (e.g. across Reshape/Transpose).
        if m.syms.shapes_equal(&tc.dims, &td.dims) {
            return true;
        }
        if m.same_size(cand, domain) {
            return true;
        }
    } else {
        // Propagation-only fallback for static shapes.
        if let (Some(a), Some(b)) = (tc.static_elems(), td.static_elems()) {
            if a == b && tc.rank() == td.rank() {
                return true;
            }
        }
    }
    false
}

/// The iteration domain a joining producer must match: the reduce *input*
/// for input-fusion groups, the root output for loop groups.
fn group_domain(m: &Module, g: &FusionGroup) -> ValueId {
    match g.kind {
        GroupKind::Input => m.instrs[g.root].operands[0],
        GroupKind::Loop => g.root,
    }
}

/// Values whose contents feed shape-operand slots anywhere in the module
/// (these are host-side shape calculations and must not fuse into device
/// kernels), transitively closed over producers.
pub fn host_shape_values(m: &Module) -> Vec<bool> {
    let mut host = vec![false; m.instrs.len()];
    let mut stack = Vec::new();
    for ins in &m.instrs {
        for &slot in ins.op.shape_operand_slots() {
            stack.push(ins.operands[slot]);
        }
    }
    // GetDimSize results are host values by construction.
    for (id, ins) in m.instrs.iter().enumerate() {
        if matches!(ins.op, Op::GetDimSize { .. }) {
            stack.push(id);
        }
    }
    while let Some(v) = stack.pop() {
        if host[v] {
            continue;
        }
        host[v] = true;
        for &o in &m.instrs[v].operands {
            stack.push(o);
        }
    }
    host
}

/// Plan fusion groups for a module.
pub fn plan(m: &Module, opts: &FusionOptions) -> FusionPlan {
    let n = m.instrs.len();
    let mut membership: Vec<Option<usize>> = vec![None; n];
    let mut groups: Vec<FusionGroup> = Vec::new();
    if !opts.enabled {
        return FusionPlan { groups, membership };
    }

    let users = m.users();
    let host = host_shape_values(m);
    let is_output: Vec<bool> = {
        let mut v = vec![false; n];
        for &o in &m.outputs {
            v[o] = true;
        }
        v
    };

    // Reverse topological sweep: try to merge each instruction into the
    // (unique) group of its consumers; otherwise root a new group.
    for id in (0..n).rev() {
        let ins = &m.instrs[id];
        if host[id]
            || !ins.op.is_fusable()
            || matches!(ins.op, Op::Param { .. } | Op::Const { .. })
        {
            continue;
        }
        let is_reduce = matches!(ins.op, Op::Reduce { .. });
        if is_reduce && !opts.enable_input_fusion {
            continue;
        }

        // Collect consumer groups. An escaping use (module output, unfused
        // user, user in no group yet) forces this instr to be a root.
        let mut consumer_groups: Vec<usize> = Vec::new();
        let mut escapes = is_output[id];
        for &u in &users[id] {
            match membership[u] {
                Some(g) => consumer_groups.push(g),
                None => escapes = true,
            }
        }
        consumer_groups.sort_unstable();
        consumer_groups.dedup();

        let joinable = !escapes
            && consumer_groups.len() == 1
            && !is_reduce  // reduce may only root an input fusion
            && {
                let g = &groups[consumer_groups[0]];
                g.len() < opts.max_group_size
                    && compatible(m, id, group_domain(m, g), opts)
            };

        if joinable {
            let gid = consumer_groups[0];
            groups[gid].members.push(id);
            membership[id] = Some(gid);
        } else if (!users[id].is_empty() || is_output[id])
            // pred never crosses the kernel boundary (no pred literal I/O),
            // and reshapes are free bitcasts handled by the executor.
            && ins.ty.dtype != crate::dhlo::DType::Pred
            && !matches!(ins.op, Op::Reshape | Op::DReshape)
        {
            let kind = if is_reduce { GroupKind::Input } else { GroupKind::Loop };
            let gid = groups.len();
            groups.push(FusionGroup { id: gid, kind, members: vec![id], root: id });
            membership[id] = Some(gid);
        }
    }

    // Members were pushed in reverse order; normalize to ascending (topo).
    for g in &mut groups {
        g.members.sort_unstable();
    }
    FusionPlan { groups, membership }
}

/// Per-plan statistics for metrics and the bench reports.
#[derive(Debug, Clone, Default)]
pub struct FusionStats {
    pub groups: usize,
    pub fused_ops: usize,
    pub singleton_groups: usize,
    pub largest_group: usize,
    pub input_fusions: usize,
}

pub fn stats(plan: &FusionPlan) -> FusionStats {
    let mut s = FusionStats { groups: plan.groups.len(), ..Default::default() };
    let mut sizes: HashMap<usize, usize> = HashMap::new();
    for g in &plan.groups {
        sizes.insert(g.id, g.len());
        s.fused_ops += g.len();
        if g.len() == 1 {
            s.singleton_groups += 1;
        }
        s.largest_group = s.largest_group.max(g.len());
        if g.kind == GroupKind::Input {
            s.input_fusions += 1;
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::shape::Dim;

    fn softmax_module() -> Module {
        let mut b = Builder::new("softmax");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let y = b.softmax_last(x).unwrap();
        b.finish(vec![y])
    }

    #[test]
    fn elementwise_chain_fuses_into_one_group() {
        let mut b = Builder::new("chain");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let a = b.unary(UnKind::Tanh, x);
        let c = b.unary(UnKind::Exp, a);
        let d = b.add(a, c).unwrap();
        let m = b.finish(vec![d]);
        let p = plan(&m, &FusionOptions::default());
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].len(), 3);
        assert_eq!(p.groups[0].root, d);
        assert_eq!(p.kernel_count(&m), 1);
    }

    #[test]
    fn softmax_splits_at_reduces() {
        let m = softmax_module();
        let p = plan(&m, &FusionOptions::default());
        // Softmax = max-reduce, sub/exp chain, sum-reduce, div chain:
        // reduces root their own input-fusion groups.
        let input_fusions = p.groups.iter().filter(|g| g.kind == GroupKind::Input).count();
        assert_eq!(input_fusions, 2, "max and sum reduces each root a group");
        // Far fewer kernels than ops.
        let total_ops = m.memory_intensive_count();
        assert!(p.kernel_count(&m) < total_ops);
    }

    #[test]
    fn input_fusion_pulls_producers_into_reduce() {
        let mut b = Builder::new("redroot");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(4)]);
        let e = b.unary(UnKind::Exp, x);
        let t = b.unary(UnKind::Tanh, e);
        let r = b.reduce(crate::dhlo::ReduceKind::Sum, t, vec![1]).unwrap();
        let m = b.finish(vec![r]);
        let p = plan(&m, &FusionOptions::default());
        assert_eq!(p.groups.len(), 1);
        assert_eq!(p.groups[0].kind, GroupKind::Input);
        assert_eq!(p.groups[0].len(), 3);
    }

    #[test]
    fn no_input_fusion_when_disabled() {
        let mut b = Builder::new("redroot");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(4)]);
        let e = b.unary(UnKind::Exp, x);
        let r = b.reduce(crate::dhlo::ReduceKind::Sum, e, vec![1]).unwrap();
        let m = b.finish(vec![r]);
        let opts = FusionOptions { enable_input_fusion: false, ..Default::default() };
        let p = plan(&m, &opts);
        // The reduce stays unfused; exp roots its own group.
        assert!(p.membership[r].is_none());
        assert_eq!(p.groups.len(), 1);
    }

    #[test]
    fn constraints_widen_fusion_scope() {
        // tanh(x)[s,4] --transpose--> [4,s] --exp--> root.
        // The tanh output's dim vector ([s,4]) differs structurally from
        // the group domain ([4,s]), so joining it needs the recorded
        // tensor-size equality (transpose size propagation). Without
        // constraints the tanh stays out of the group.
        let mut b = Builder::new("c");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(4)]);
        let t = b.unary(UnKind::Tanh, x);
        let tr = b.transpose(t, vec![1, 0]).unwrap();
        let e = b.unary(UnKind::Exp, tr);
        let m = b.finish(vec![e]);

        let with = plan(&m, &FusionOptions::default());
        let without =
            plan(&m, &FusionOptions { use_constraints: false, ..Default::default() });
        let t_with = with.membership[t].is_some() && with.membership[t] == with.membership[e];
        let t_without =
            without.membership[t].is_some() && without.membership[t] == without.membership[e];
        assert!(t_with, "constraints should fuse tanh across the transpose");
        assert!(!t_without, "without constraints the tanh cannot join");
    }

    #[test]
    fn fusion_disabled_yields_empty_plan() {
        let m = softmax_module();
        let p = plan(&m, &FusionOptions { enabled: false, ..Default::default() });
        assert!(p.groups.is_empty());
        assert_eq!(p.kernel_count(&m), m.memory_intensive_count());
    }

    #[test]
    fn dot_never_fuses() {
        let mut b = Builder::new("d");
        let s = b.dyn_dim("m", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let w = b.param(DType::F32, vec![Dim::Fixed(8), Dim::Fixed(8)]);
        let d = b.dot(x, w).unwrap();
        let y = b.unary(UnKind::Relu, d);
        let m = b.finish(vec![y]);
        let p = plan(&m, &FusionOptions::default());
        assert!(p.membership[d].is_none());
        assert!(p.membership[y].is_some());
    }

    #[test]
    fn host_shape_values_not_fused() {
        let mut b = Builder::new("h");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let st = b.i64_vec(&[0]);
        let li = b.i64_vec(&[2]);
        let sr = b.i64_vec(&[1]);
        // An i64 computation feeding the slice bounds: host-side.
        let li2 = b.add(li, sr).unwrap();
        let sl = b.dslice(x, st, li2, sr).unwrap();
        let m = b.finish(vec![sl]);
        let host = host_shape_values(&m);
        assert!(host[li2] && host[li] && host[sr] && host[st]);
        assert!(!host[sl] && !host[x]);
        let p = plan(&m, &FusionOptions::default());
        assert!(p.membership[li2].is_none(), "host shape math must not fuse");
    }
}
