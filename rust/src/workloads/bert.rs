//! BERT workload (PyTorch flavour, batch 1) — the §5.1 case study model
//! (vs PyTorch and vs TensorRT).
//!
//! Same encoder backbone as the Transformer workload, plus BERT's
//! distinctive pieces: token + segment + position embedding sum with an
//! embedding layernorm in front, and a tanh pooler head over the first
//! token at the end.

use super::transformer::{encoder_layer, HIDDEN, VOCAB};
use super::Workload;
use crate::dhlo::{BinKind, DType, UnKind};
use crate::graph::{GOp, Graph, GraphBuilder};
use crate::runtime::tensor::Tensor;
use crate::util::prng::Prng;

pub const LAYERS: usize = 2;
pub const SEGMENTS: usize = 2;

pub fn graph() -> Graph {
    let mut gb = GraphBuilder::new("bert");
    let ids = gb.placeholder("input_ids", DType::I64, &[-1]);
    let seg_ids = gb.placeholder("segment_ids", DType::I64, &[-1]);
    let pos = gb.placeholder("position_enc", DType::F32, &[-1, HIDDEN as i64]);

    let tok_table = gb.weight("tok_embeddings", &[VOCAB, HIDDEN], 300);
    let seg_table = gb.weight("seg_embeddings", &[SEGMENTS, HIDDEN], 301);
    let tok = gb.gather("tok", tok_table, ids, 0);
    let seg = gb.gather("seg", seg_table, seg_ids, 0);
    let sum1 = gb.binary("tok_seg", BinKind::Add, tok, seg);
    let summed = gb.binary("emb_sum", BinKind::Add, sum1, pos);
    let g0 = gb.weight("emb_ln_g", &[HIDDEN], 302);
    let b0 = gb.weight("emb_ln_b", &[HIDDEN], 303);
    let mut x = gb.layernorm("emb_ln", summed, g0, b0);

    for layer in 0..LAYERS {
        x = encoder_layer(&mut gb, x, layer, 400 + 50 * layer as u64);
    }

    // Pooler: first token -> dense -> tanh.
    let first = gb.add(
        "first_token",
        GOp::Slice { begin: vec![0, 0], size: vec![1, HIDDEN as i64] },
        &[x],
    );
    let wp = gb.weight("pooler_w", &[HIDDEN, HIDDEN], 500);
    let bp = gb.weight("pooler_b", &[HIDDEN], 501);
    let pooled = gb.matmul("pooled", first, wp);
    let pooled_b = gb.bias_add("pooled_b", pooled, bp);
    let out = gb.unary("pooler_tanh", UnKind::Tanh, pooled_b);
    gb.finish(&[x, out])
}

pub fn gen_inputs(seq: usize, rng: &mut Prng) -> Vec<Tensor> {
    vec![
        Tensor::i64(&[seq], rng.fill_i64(seq, 0, VOCAB as i64 - 1)),
        Tensor::i64(&[seq], rng.fill_i64(seq, 0, SEGMENTS as i64 - 1)),
        Tensor::f32(&[seq, HIDDEN], rng.fill_f32(seq * HIDDEN, 0.1)),
    ]
}

pub fn workload() -> Workload {
    Workload {
        name: "bert",
        framework: "PyTorch",
        batch: 1,
        graph: graph(),
        seq_range: (32, 160),
        gen: Box::new(gen_inputs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};
    use crate::runtime::reference::eval_module;

    #[test]
    fn bert_all_modes_agree() {
        let compiler = DiscCompiler::new().unwrap();
        let mut rng = Prng::new(4);
        let inputs = gen_inputs(21, &mut rng);
        let reference = {
            let m = crate::bridge::lower(&graph()).unwrap();
            eval_module(&m, &inputs).unwrap()
        };
        for mode in [Mode::Eager, Mode::VmNimble, Mode::Disc] {
            let m = crate::bridge::lower(&graph()).unwrap();
            let mut model = compiler.compile(m, &CompileOptions::mode(mode)).unwrap();
            let got = model.run(&inputs).unwrap();
            assert_eq!(got.outputs[1].dims, vec![1, HIDDEN]);
            assert!(
                got.outputs[0].allclose(&reference.outputs[0], 5e-4, 5e-4).unwrap(),
                "{mode:?} disagrees"
            );
        }
    }
}
