//! ASR workload (both TensorFlow and PyTorch rows of Table 1, batch 1).
//!
//! A listen-attend style acoustic model over a dynamic-length feature
//! sequence `[T, FEAT]`: a dense pre-net, two gated (GLU-ish) blocks whose
//! TF variant produces both halves with one matmul + `Split` (exercising
//! the bridge's constraint injection) while the PyTorch variant uses two
//! separate projections (`torch.chunk`-free), then attention pooling over
//! the dynamic time axis and a classifier head.

use super::Workload;
use crate::dhlo::{BinKind, DType, ReduceKind, UnKind};
use crate::graph::{Edge, Graph, GraphBuilder};
use crate::runtime::tensor::Tensor;
use crate::util::prng::Prng;

pub const FEAT: usize = 40;
pub const HIDDEN: usize = 64;
pub const CLASSES: usize = 32;

fn prenet(gb: &mut GraphBuilder, x: Edge, seed: u64) -> Edge {
    let w = gb.weight("pre_w", &[FEAT, HIDDEN], seed);
    let b = gb.weight("pre_b", &[HIDDEN], seed + 1);
    let h = gb.matmul("pre_h", x, w);
    let hb = gb.bias_add("pre_hb", h, b);
    gb.unary("pre_act", UnKind::Relu, hb)
}

/// Gated block, TF style: one `[H, 2H]` matmul then `Split` into the value
/// and gate halves (the paper's constraint-injection example in the wild).
fn gated_block_tf(gb: &mut GraphBuilder, x: Edge, idx: usize, seed: u64) -> Edge {
    let p = |s: &str| format!("g{idx}_{s}");
    let w = gb.weight(&p("w"), &[HIDDEN, 2 * HIDDEN], seed);
    let b = gb.weight(&p("b"), &[2 * HIDDEN], seed + 1);
    let h = gb.matmul(&p("h"), x, w);
    let hb = gb.bias_add(&p("hb"), h, b);
    let halves = gb.split(&p("split"), hb, 1, 2);
    let val = gb.unary(&p("val"), UnKind::Tanh, halves[0]);
    let gate = gb.unary(&p("gate"), UnKind::Sigmoid, halves[1]);
    let gated = gb.binary(&p("gated"), BinKind::Mul, val, gate);
    gb.binary(&p("res"), BinKind::Add, x, gated)
}

/// Gated block, PyTorch style: two separate projections.
fn gated_block_pt(gb: &mut GraphBuilder, x: Edge, idx: usize, seed: u64) -> Edge {
    let p = |s: &str| format!("g{idx}_{s}");
    let wv = gb.weight(&p("wv"), &[HIDDEN, HIDDEN], seed);
    let wg = gb.weight(&p("wg"), &[HIDDEN, HIDDEN], seed + 1);
    let hv = gb.matmul(&p("hv"), x, wv);
    let hg = gb.matmul(&p("hg"), x, wg);
    let val = gb.unary(&p("val"), UnKind::Tanh, hv);
    let gate = gb.unary(&p("gate"), UnKind::Sigmoid, hg);
    let gated = gb.binary(&p("gated"), BinKind::Mul, val, gate);
    gb.binary(&p("res"), BinKind::Add, x, gated)
}

/// Attention pooling over the dynamic time axis + classifier.
fn head(gb: &mut GraphBuilder, h: Edge, seed: u64) -> Edge {
    let wa = gb.weight("attn_w", &[HIDDEN, 1], seed);
    let scores = gb.matmul("attn_scores", h, wa); // [T, 1]
    let scores_t = gb.transpose("attn_scores_t", scores, &[1, 0]); // [1, T]
    let attn = gb.softmax("attn_softmax", scores_t); // softmax over dynamic T
    let pooled = gb.matmul("attn_pooled", attn, h); // [1, H]
    let wc = gb.weight("cls_w", &[HIDDEN, CLASSES], seed + 1);
    let bc = gb.weight("cls_b", &[CLASSES], seed + 2);
    let logits = gb.matmul("logits", pooled, wc);
    let logits_b = gb.bias_add("logits_b", logits, bc);
    gb.softmax("probs", logits_b)
}

fn build(tf: bool) -> Graph {
    let mut gb = GraphBuilder::new(if tf { "asr_tf" } else { "asr_pt" });
    let x = gb.placeholder("features", DType::F32, &[-1, FEAT as i64]);
    let mut h = prenet(&mut gb, x, 600);
    for i in 0..2 {
        h = if tf {
            gated_block_tf(&mut gb, h, i, 700 + 20 * i as u64)
        } else {
            gated_block_pt(&mut gb, h, i, 700 + 20 * i as u64)
        };
        let g = gb.weight(&format!("ln{i}_g"), &[HIDDEN], 800 + i as u64);
        let b = gb.weight(&format!("ln{i}_b"), &[HIDDEN], 810 + i as u64);
        h = gb.layernorm(&format!("ln{i}"), h, g, b);
    }
    let out = head(&mut gb, h, 900);
    // Reduce over time axis too (frame-level aux output), keeping the
    // dynamic reduction in the mix.
    let frame_mean = gb.reduce("frame_mean", ReduceKind::Mean, h, &[0]);
    gb.finish(&[out, frame_mean])
}

pub fn gen_inputs(seq: usize, rng: &mut Prng) -> Vec<Tensor> {
    vec![Tensor::f32(&[seq, FEAT], rng.fill_f32(seq * FEAT, 0.5))]
}

pub fn workload_tf() -> Workload {
    Workload {
        name: "asr_tf",
        framework: "TensorFlow",
        batch: 1,
        graph: build(true),
        seq_range: (20, 120),
        gen: Box::new(gen_inputs),
    }
}

pub fn workload_pt() -> Workload {
    Workload {
        name: "asr_pt",
        framework: "PyTorch",
        batch: 1,
        graph: build(false),
        seq_range: (20, 120),
        gen: Box::new(gen_inputs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};
    use crate::runtime::reference::eval_module;

    #[test]
    fn asr_tf_split_lowering_runs_compiled() {
        let w = workload_tf();
        let m = crate::bridge::lower(&w.graph).unwrap();
        // The TF variant must contain dynamic slices from Split lowering.
        assert!(m.instrs.iter().any(|i| matches!(i.op, crate::dhlo::Op::DSlice)));
        let compiler = DiscCompiler::new().unwrap();
        let mut model = compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap();
        let mut rng = Prng::new(6);
        let inputs = gen_inputs(33, &mut rng);
        let got = model.run(&inputs).unwrap();
        let want = eval_module(model.module(), &inputs).unwrap();
        assert!(got.outputs[0].allclose(&want.outputs[0], 5e-4, 5e-4).unwrap());
        assert!(got.outputs[1].allclose(&want.outputs[1], 5e-4, 5e-4).unwrap());
    }

    #[test]
    fn asr_variants_structurally_differ() {
        let tf = crate::bridge::lower(&workload_tf().graph).unwrap();
        let pt = crate::bridge::lower(&workload_pt().graph).unwrap();
        let tf_has_dslice = tf.instrs.iter().any(|i| matches!(i.op, crate::dhlo::Op::DSlice));
        let pt_has_dslice = pt.instrs.iter().any(|i| matches!(i.op, crate::dhlo::Op::DSlice));
        assert!(tf_has_dslice && !pt_has_dslice);
    }
}
