//! Seq2seq workload (PyTorch flavour, batch 64).
//!
//! Encoder over a dynamic-length batched token tensor `[B, S]` (embedding
//! via flattened gather, dense + tanh, masked-free mean pooling over the
//! dynamic time axis) and a single decoder step (gated cell + vocabulary
//! softmax). The batch axis is static (64, per Table 1); the sequence axis
//! is the dynamism driver. The growing time axis and the gated cell come
//! from the shared decode driver (`workloads::decode`).

use super::decode::{gate_pair, time_axis_ids};
use super::Workload;
use crate::dhlo::{BinKind, DType, ReduceKind, UnKind};
use crate::graph::{Graph, GraphBuilder};
use crate::runtime::tensor::Tensor;
use crate::util::prng::Prng;

pub const BATCH: usize = 64;
pub const EMB: usize = 32;
pub const HIDDEN: usize = 64;
pub const VOCAB: usize = 256;

pub fn graph() -> Graph {
    let mut gb = GraphBuilder::new("seq2seq");
    // [B*S] flattened ids (PyTorch-style view) with dynamic S.
    let ids = time_axis_ids(&mut gb, "src_ids");
    let prev = gb.placeholder("prev_emb", DType::F32, &[BATCH as i64, EMB as i64]);

    let table = gb.weight("src_embedding", &[VOCAB, EMB], 2000);
    let flat = gb.gather("emb_flat", table, ids, 0); // [B*S, E]
    // View as [B, S, E]: batch static, S inferred.
    let emb = gb.reshape("emb", flat, &[BATCH as i64, -1, EMB as i64]);

    // Encoder dense+tanh applied over the flattened time dim.
    let flat2 = gb.reshape("enc_in", emb, &[-1, EMB as i64]);
    let we = gb.weight("enc_w", &[EMB, HIDDEN], 2001);
    let be = gb.weight("enc_b", &[HIDDEN], 2002);
    let eh = gb.matmul("enc_h", flat2, we);
    let ehb = gb.bias_add("enc_hb", eh, be);
    let ea = gb.unary("enc_act", UnKind::Tanh, ehb);
    let enc = gb.reshape("enc", ea, &[BATCH as i64, -1, HIDDEN as i64]); // [B, S, H]

    // Mean-pool over the dynamic time axis → context [B, H].
    let ctx = gb.reduce("ctx", ReduceKind::Mean, enc, &[1]);

    // Decoder step: gated cell over (prev token embedding, context).
    let wi = gb.weight("dec_wi", &[EMB, HIDDEN], 2010);
    let wc = gb.weight("dec_wc", &[HIDDEN, HIDDEN], 2011);
    let xi = gb.matmul("dec_xi", prev, wi); // [B, H]
    let xc = gb.matmul("dec_xc", ctx, wc); // [B, H]
    let pre = gb.binary("dec_pre", BinKind::Add, xi, xc);
    let (z, cand) = gate_pair(&mut gb, "dec_", pre, pre);
    let gated = gb.binary("dec_gated", BinKind::Mul, z, cand);
    let state = gb.binary("dec_state", BinKind::Add, gated, xc); // [B, H]

    // Vocabulary head.
    let wo = gb.weight("dec_wo", &[HIDDEN, VOCAB], 2012);
    let bo = gb.weight("dec_bo", &[VOCAB], 2013);
    let logits = gb.matmul("logits", state, wo);
    let logits_b = gb.bias_add("logits_b", logits, bo);
    let probs = gb.softmax("probs", logits_b); // [B, V]
    gb.finish(&[probs, ctx])
}

pub fn gen_inputs(seq: usize, rng: &mut Prng) -> Vec<Tensor> {
    vec![
        Tensor::i64(&[BATCH * seq], rng.fill_i64(BATCH * seq, 0, VOCAB as i64 - 1)),
        Tensor::f32(&[BATCH, EMB], rng.fill_f32(BATCH * EMB, 0.5)),
    ]
}

pub fn workload() -> Workload {
    Workload {
        name: "seq2seq",
        framework: "PyTorch",
        batch: BATCH,
        graph: graph(),
        seq_range: (8, 48),
        gen: Box::new(gen_inputs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};
    use crate::runtime::reference::eval_module;

    #[test]
    fn seq2seq_batched_dynamic_time() {
        let w = workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        let mut model = compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap();
        let mut rng = Prng::new(10);
        for seq in [9usize, 16] {
            let inputs = gen_inputs(seq, &mut rng);
            let got = model.run(&inputs).unwrap();
            let want = eval_module(model.module(), &inputs).unwrap();
            assert_eq!(got.outputs[0].dims, vec![BATCH, VOCAB]);
            assert!(got.outputs[0].allclose(&want.outputs[0], 5e-4, 5e-4).unwrap());
            // Probabilities sum to ~1 per row.
            let row: f32 = got.outputs[0].as_f32().unwrap()[..VOCAB].iter().sum();
            assert!((row - 1.0).abs() < 1e-3);
        }
    }
}
