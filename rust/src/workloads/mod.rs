//! The evaluation workloads (paper Table 1).
//!
//! | Model       | Framework  | Batch |
//! |-------------|------------|-------|
//! | ASR         | TensorFlow | 1     |
//! | ASR         | PyTorch    | 1     |
//! | Seq2seq     | PyTorch    | 64    |
//! | TTS         | TensorFlow | 1     |
//! | BERT        | PyTorch    | 1     |
//! | Ad Ranking  | TensorFlow | 512   |
//! | Transformer | TensorFlow | 1     |
//!
//! Plus one beyond Table 1: `decode`, a single autoregressive decode step
//! over a bucket-capacity KV slab, driving the serving stack's decode mode
//! (see `workloads::decode` and `runtime/kv.rs`).
//!
//! The paper's models are proprietary; these are structurally
//! representative stand-ins (see DESIGN.md §3): the op mixes (attention
//! blocks, layernorm/softmax expansions, gated RNN cells, embedding +
//! Unique sparse lookups, MLP towers) and the dynamism axes (sequence
//! length, id-list length) match what the paper exercises, at hidden sizes
//! sized for a CPU testbed. Weights are embedded as deterministic constants
//! so a request carries only activations.

pub mod ad_ranking;
pub mod asr;
pub mod bert;
pub mod decode;
pub mod seq2seq;
pub mod transformer;
pub mod tts;

use crate::graph::Graph;
use crate::runtime::tensor::Tensor;
use crate::util::prng::Prng;

/// A runnable workload: its graph plus a request generator.
pub struct Workload {
    pub name: &'static str,
    pub framework: &'static str,
    pub batch: usize,
    pub graph: Graph,
    /// Dynamic-extent range a request stream samples from (the "sequence
    /// length" axis of the workload).
    pub seq_range: (usize, usize),
    /// Generate request inputs for a given dynamic extent.
    pub gen: Box<dyn Fn(usize, &mut Prng) -> Vec<Tensor>>,
}

impl Workload {
    /// Sample a request stream of `n` requests (deterministic per seed).
    pub fn request_stream(&self, n: usize, seed: u64) -> Vec<Vec<Tensor>> {
        let mut rng = Prng::new(seed);
        (0..n)
            .map(|_| {
                let seq = rng.range(self.seq_range.0, self.seq_range.1);
                (self.gen)(seq, &mut rng)
            })
            .collect()
    }
}

/// All Table 1 rows, in the paper's order.
pub fn all() -> Vec<Workload> {
    vec![
        asr::workload_tf(),
        asr::workload_pt(),
        seq2seq::workload(),
        tts::workload(),
        bert::workload(),
        ad_ranking::workload(),
        transformer::workload(),
        decode::workload(),
    ]
}

/// Look up a workload by CLI name.
pub fn by_name(name: &str) -> Option<Workload> {
    match name {
        "asr_tf" | "asr" => Some(asr::workload_tf()),
        "asr_pt" => Some(asr::workload_pt()),
        "seq2seq" => Some(seq2seq::workload()),
        "tts" => Some(tts::workload()),
        "bert" => Some(bert::workload()),
        "ad_ranking" | "ads" => Some(ad_ranking::workload()),
        "transformer" => Some(transformer::workload()),
        "decode" => Some(decode::workload()),
        _ => None,
    }
}

pub const NAMES: [&str; 8] =
    ["asr_tf", "asr_pt", "seq2seq", "tts", "bert", "ad_ranking", "transformer", "decode"];

/// Freeze a workload graph's dynamic placeholder dims to `fixed` (consumed
/// in placeholder order). Used by the Fig. 4 bench to build the
/// static-compiler comparison graph for a given input size.
pub fn make_static(g: &Graph, fixed_extent: usize) -> Graph {
    let mut out = g.clone();
    for node in &mut out.nodes {
        if let crate::graph::GOp::Placeholder { dims, .. } = &mut node.op {
            for d in dims.iter_mut() {
                if *d < 0 {
                    *d = fixed_extent as i64;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::reference::eval_module;

    /// Every workload lowers, verifies, and evaluates on a couple of
    /// dynamic extents — the broad structural smoke test.
    #[test]
    fn all_workloads_lower_and_evaluate() {
        for w in all() {
            let m = crate::bridge::lower(&w.graph)
                .unwrap_or_else(|e| panic!("{}: lowering failed: {e:#}", w.name));
            let mut rng = Prng::new(1);
            for seq in [w.seq_range.0, (w.seq_range.0 + w.seq_range.1) / 2] {
                let inputs = (w.gen)(seq, &mut rng);
                let r = eval_module(&m, &inputs)
                    .unwrap_or_else(|e| panic!("{}: eval at {seq} failed: {e:#}", w.name));
                assert!(!r.outputs.is_empty(), "{}", w.name);
                assert!(r.launches > 3, "{} should be non-trivial", w.name);
            }
        }
    }

    #[test]
    fn workloads_have_dynamic_shapes() {
        for w in all() {
            let m = crate::bridge::lower(&w.graph).unwrap();
            assert!(
                !m.is_fully_static(),
                "{} must exercise dynamic shapes (that is the paper's point)",
                w.name
            );
        }
    }

    #[test]
    fn request_streams_are_deterministic() {
        let w = transformer::workload();
        let a = w.request_stream(3, 9);
        let b = w.request_stream(3, 9);
        for (x, y) in a.iter().zip(&b) {
            for (tx, ty) in x.iter().zip(y) {
                assert_eq!(tx, ty);
            }
        }
    }
}
