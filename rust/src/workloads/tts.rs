//! TTS workload (TensorFlow flavour, batch 1): a Tacotron-style decoder
//! step conditioned on a dynamic-length encoder memory.
//!
//! Inputs: encoder memory `[S, H]` (dynamic S) and the previous mel frame.
//! Pre-net (dense + relu ×2) → additive attention over the memory →
//! GRU-flavoured gated update → post-net (dense + tanh ×3) emitting the
//! next mel frame. Heavy on small elementwise/broadcast/reduce ops — the
//! shape of workload where the paper's fusion shines. The growing time
//! axis, the additive-attention energies, and the gated cell come from the
//! shared decode driver (`workloads::decode`).

use super::decode::{additive_energy, gate_pair, time_axis};
use super::Workload;
use crate::dhlo::{BinKind, DType, UnKind};
use crate::graph::{Graph, GraphBuilder};
use crate::runtime::tensor::Tensor;
use crate::util::prng::Prng;

pub const HIDDEN: usize = 64;
pub const MEL: usize = 20;

pub fn graph() -> Graph {
    let mut gb = GraphBuilder::new("tts");
    let memory = time_axis(&mut gb, "memory", HIDDEN);
    let prev = gb.placeholder("prev_frame", DType::F32, &[1, MEL as i64]);

    // Pre-net.
    let w1 = gb.weight("pre_w1", &[MEL, HIDDEN], 1000);
    let b1 = gb.weight("pre_b1", &[HIDDEN], 1001);
    let h1 = gb.matmul("pre_h1", prev, w1);
    let h1b = gb.bias_add("pre_h1b", h1, b1);
    let a1 = gb.unary("pre_a1", UnKind::Relu, h1b);
    let w2 = gb.weight("pre_w2", &[HIDDEN, HIDDEN], 1002);
    let b2 = gb.weight("pre_b2", &[HIDDEN], 1003);
    let h2 = gb.matmul("pre_h2", a1, w2);
    let h2b = gb.bias_add("pre_h2b", h2, b2);
    let query = gb.unary("pre_a2", UnKind::Relu, h2b); // [1, H]

    // Additive attention: tanh(mem W + query W') v over dynamic S.
    let wm = gb.weight("attn_wm", &[HIDDEN, HIDDEN], 1010);
    let wq = gb.weight("attn_wq", &[HIDDEN, HIDDEN], 1011);
    let keys = gb.matmul("attn_keys", memory, wm); // [S, H]
    let qproj = gb.matmul("attn_q", query, wq); // [1, H]
    // Broadcast the query row over the sequence: keys + q.
    let qrow = gb.reshape("attn_q_row", qproj, &[HIDDEN as i64]); // [H]
    let v = gb.weight("attn_v", &[HIDDEN, 1], 1012);
    let scores = additive_energy(&mut gb, "attn_", keys, qrow, v); // [S, 1]
    let scores_t = gb.transpose("attn_scores_t", scores, &[1, 0]); // [1, S]
    let weights = gb.softmax("attn_weights", scores_t);
    let context = gb.matmul("attn_ctx", weights, memory); // [1, H]

    // Gated update (GRU-ish).
    let wz = gb.weight("gate_wz", &[HIDDEN, HIDDEN], 1020);
    let wh = gb.weight("gate_wh", &[HIDDEN, HIDDEN], 1021);
    let zi = gb.matmul("gate_zi", context, wz);
    let zq = gb.matmul("gate_zq", query, wh);
    let zsum = gb.binary("gate_zsum", BinKind::Add, zi, zq);
    let cand_in = gb.binary("gate_cand_in", BinKind::Add, context, query);
    let (z, cand) = gate_pair(&mut gb, "gate_", zsum, cand_in);
    let one = gb.weight("one", &[HIDDEN], 1022);
    let zneg = gb.unary("gate_zneg", UnKind::Neg, z);
    let one_minus = gb.binary("gate_one_minus", BinKind::Add, zneg, one);
    let keep = gb.binary("gate_keep", BinKind::Mul, z, query);
    let update = gb.binary("gate_update", BinKind::Mul, one_minus, cand);
    let state = gb.binary("gate_state", BinKind::Add, keep, update); // [1, H]

    // Post-net.
    let mut h = state;
    for i in 0..3 {
        let wo = gb.weight(
            &format!("post_w{i}"),
            &[HIDDEN, if i == 2 { MEL } else { HIDDEN }],
            1030 + i as u64,
        );
        let t = gb.matmul(&format!("post_h{i}"), h, wo);
        h = gb.unary(&format!("post_t{i}"), UnKind::Tanh, t);
    }
    gb.finish(&[h, weights])
}

pub fn gen_inputs(seq: usize, rng: &mut Prng) -> Vec<Tensor> {
    vec![
        Tensor::f32(&[seq, HIDDEN], rng.fill_f32(seq * HIDDEN, 0.5)),
        Tensor::f32(&[1, MEL], rng.fill_f32(MEL, 0.5)),
    ]
}

pub fn workload() -> Workload {
    Workload {
        name: "tts",
        framework: "TensorFlow",
        batch: 1,
        graph: graph(),
        seq_range: (24, 160),
        gen: Box::new(gen_inputs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};
    use crate::runtime::reference::eval_module;

    #[test]
    fn tts_decoder_step_compiles_and_matches() {
        let w = workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        let mut model = compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap();
        let mut rng = Prng::new(8);
        for seq in [24usize, 57] {
            let inputs = gen_inputs(seq, &mut rng);
            let got = model.run(&inputs).unwrap();
            let want = eval_module(model.module(), &inputs).unwrap();
            assert_eq!(got.outputs[0].dims, vec![1, MEL]);
            assert_eq!(got.outputs[1].dims, vec![1, seq]);
            assert!(got.outputs[0].allclose(&want.outputs[0], 5e-4, 5e-4).unwrap());
        }
    }
}
