//! Transformer encoder workload (TensorFlow flavour, batch 1) — the model
//! the paper uses for its §5.2 Nimble comparison and Table 2/3 breakdowns.
//!
//! Token ids (dynamic sequence length) → embedding → N encoder layers of
//! multi-head attention + FFN, each with residual + layernorm. Multi-head
//! reshaping goes through `Reshape`/`Transpose`, attention through batched
//! matmuls, scores through scaled softmax over the *dynamic* time axis —
//! exactly the memory-intensive op mix whose fusion the paper measures.

use super::Workload;
use crate::dhlo::{BinKind, DType, UnKind};
use crate::graph::{Edge, Graph, GraphBuilder};
use crate::runtime::tensor::Tensor;
use crate::util::prng::Prng;

pub const HIDDEN: usize = 128;
pub const HEADS: usize = 4;
pub const HEAD_DIM: usize = HIDDEN / HEADS;
pub const FFN: usize = 256;
pub const VOCAB: usize = 512;
pub const LAYERS: usize = 2;

/// One encoder layer; returns the layer output `[S, HIDDEN]`.
pub fn encoder_layer(gb: &mut GraphBuilder, x: Edge, layer: usize, seed: u64) -> Edge {
    let p = |s: &str| format!("l{layer}_{s}");
    let wq = gb.weight(&p("wq"), &[HIDDEN, HIDDEN], seed + 1);
    let wk = gb.weight(&p("wk"), &[HIDDEN, HIDDEN], seed + 2);
    let wv = gb.weight(&p("wv"), &[HIDDEN, HIDDEN], seed + 3);
    let wo = gb.weight(&p("wo"), &[HIDDEN, HIDDEN], seed + 4);

    // Projections [S, H].
    let q = gb.matmul(&p("q"), x, wq);
    let k = gb.matmul(&p("k"), x, wk);
    let v = gb.matmul(&p("v"), x, wv);

    // Split heads: [S, H] -> [S, heads, hd] -> [heads, S, hd].
    let split = |gb: &mut GraphBuilder, t: Edge, nm: &str| -> Edge {
        let r = gb.reshape(&format!("{nm}_r"), t, &[-1, HEADS as i64, HEAD_DIM as i64]);
        gb.transpose(&format!("{nm}_t"), r, &[1, 0, 2])
    };
    let qh = split(gb, q, &p("qh"));
    let kh = split(gb, k, &p("kh"));
    let vh = split(gb, v, &p("vh"));

    // Scores [heads, S, S], scaled softmax over the dynamic axis.
    let kt = gb.transpose(&p("kt"), kh, &[0, 2, 1]);
    let scores = gb.matmul(&p("scores"), qh, kt);
    let scaled = gb.scale(&p("scaled"), scores, 1.0 / (HEAD_DIM as f32).sqrt());
    let attn = gb.softmax(&p("attn"), scaled);

    // Context [heads, S, hd] -> [S, H].
    let ctx = gb.matmul(&p("ctx"), attn, vh);
    let ctx_t = gb.transpose(&p("ctx_t"), ctx, &[1, 0, 2]);
    let merged = gb.reshape(&p("merged"), ctx_t, &[-1, HIDDEN as i64]);
    let proj = gb.matmul(&p("proj"), merged, wo);

    // Residual + LN.
    let res1 = gb.binary(&p("res1"), BinKind::Add, x, proj);
    let g1 = gb.weight(&p("g1"), &[HIDDEN], seed + 5);
    let b1 = gb.weight(&p("b1"), &[HIDDEN], seed + 6);
    let ln1 = gb.layernorm(&p("ln1"), res1, g1, b1);

    // FFN with gelu.
    let w1 = gb.weight(&p("w1"), &[HIDDEN, FFN], seed + 7);
    let w2 = gb.weight(&p("w2"), &[FFN, HIDDEN], seed + 8);
    let bias1 = gb.weight(&p("bias1"), &[FFN], seed + 9);
    let bias2 = gb.weight(&p("bias2"), &[HIDDEN], seed + 10);
    let h1 = gb.matmul(&p("h1"), ln1, w1);
    let h1b = gb.bias_add(&p("h1b"), h1, bias1);
    let act = gb.unary(&p("act"), UnKind::Gelu, h1b);
    let h2 = gb.matmul(&p("h2"), act, w2);
    let h2b = gb.bias_add(&p("h2b"), h2, bias2);
    let res2 = gb.binary(&p("res2"), BinKind::Add, ln1, h2b);
    let g2 = gb.weight(&p("g2"), &[HIDDEN], seed + 11);
    let b2 = gb.weight(&p("b2"), &[HIDDEN], seed + 12);
    gb.layernorm(&p("ln2"), res2, g2, b2)
}

pub fn graph() -> Graph {
    let mut gb = GraphBuilder::new("transformer");
    // Token ids with dynamic sequence length (batch 1, TF-style flat ids).
    let ids = gb.placeholder("ids", DType::I64, &[-1]);
    let table = gb.weight("embedding", &[VOCAB, HIDDEN], 100);
    let pos = gb.placeholder("pos_enc", DType::F32, &[-1, HIDDEN as i64]);
    let emb = gb.gather("emb", table, ids, 0);
    let mut x = gb.binary("emb_pos", BinKind::Add, emb, pos);
    for layer in 0..LAYERS {
        x = encoder_layer(&mut gb, x, layer, 200 + 50 * layer as u64);
    }
    gb.finish(&[x])
}

pub fn gen_inputs(seq: usize, rng: &mut Prng) -> Vec<Tensor> {
    let ids = Tensor::i64(&[seq], rng.fill_i64(seq, 0, VOCAB as i64 - 1));
    let pos = Tensor::f32(&[seq, HIDDEN], rng.fill_f32(seq * HIDDEN, 0.1));
    vec![ids, pos]
}

pub fn workload() -> Workload {
    Workload {
        name: "transformer",
        framework: "TensorFlow",
        batch: 1,
        graph: graph(),
        seq_range: (32, 160),
        gen: Box::new(gen_inputs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};
    use crate::runtime::reference::eval_module;

    #[test]
    fn transformer_runs_through_disc_with_dynamic_lengths() {
        let w = workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        let mut model = compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap();
        let mut rng = Prng::new(2);
        for seq in [17usize, 31] {
            let inputs = gen_inputs(seq, &mut rng);
            let got = model.run(&inputs).unwrap();
            let want = eval_module(model.module(), &inputs).unwrap();
            assert_eq!(got.outputs[0].dims, vec![seq, HIDDEN]);
            assert!(
                got.outputs[0].allclose(&want.outputs[0], 5e-4, 5e-4).unwrap(),
                "seq {seq}: max diff {}",
                got.outputs[0].max_abs_diff(&want.outputs[0]).unwrap()
            );
        }
    }
}
