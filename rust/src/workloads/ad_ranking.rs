//! Ad-ranking workload (TensorFlow flavour, batch 512) — the sparse
//! recommendation model of Table 1, driving the `tf.Unique` dynamic-shape
//! path the paper calls out ("sparse workloads with Unique ops generating
//! output tensors with varying shapes").
//!
//! A variable-length id list goes through `Unique` (data-dependent output
//! length!) → embedding gather → mean pooling, is joined with dense
//! features, and feeds a 3-layer ReLU ranking tower with a sigmoid score.

use super::Workload;
use crate::dhlo::{BinKind, DType, Literal, ReduceKind, UnKind};
use crate::graph::{GOp, Graph, GraphBuilder};
use crate::runtime::tensor::Tensor;
use crate::util::prng::Prng;

pub const BATCH: usize = 512;
pub const DENSE: usize = 16;
pub const EMB: usize = 16;
pub const VOCAB: usize = 1024;
pub const TOWER: usize = 64;

pub fn graph() -> Graph {
    let mut gb = GraphBuilder::new("ad_ranking");
    let dense = gb.placeholder("dense_features", DType::F32, &[BATCH as i64, DENSE as i64]);
    // Variable-length sparse id list shared by the batch (e.g. page ids).
    let ids = gb.placeholder("sparse_ids", DType::I64, &[-1]);

    // Sparse branch: unique → gather → mean pool.
    let uniq = gb.unique("uniq", ids);
    let table = gb.weight("id_embedding", &[VOCAB, EMB], 3000);
    let emb = gb.gather("emb", table, uniq, 0); // [U, E] with data-dep U
    let pooled = gb.reduce("pooled", ReduceKind::Mean, emb, &[0]); // [E]

    // Broadcast pooled embedding over the batch and join with dense.
    let zeros = gb.add(
        "zeros",
        GOp::Const { lit: Literal::F32(vec![0.0; BATCH * EMB]), dims: vec![BATCH, EMB] },
        &[],
    );
    let pooled_b = gb.binary("pooled_b", BinKind::Add, zeros, pooled); // [B, E]
    let joined = gb.concat("joined", &[dense, pooled_b], 1); // [B, D+E]

    // Ranking tower.
    let mut h = joined;
    let mut in_dim = DENSE + EMB;
    for (i, out_dim) in [TOWER, TOWER, 1].iter().enumerate() {
        let w = gb.weight(&format!("tower_w{i}"), &[in_dim, *out_dim], 3010 + i as u64);
        let b = gb.weight(&format!("tower_b{i}"), &[*out_dim], 3020 + i as u64);
        let t = gb.matmul(&format!("tower_h{i}"), h, w);
        let tb = gb.bias_add(&format!("tower_hb{i}"), t, b);
        h = if i < 2 {
            gb.unary(&format!("tower_a{i}"), UnKind::Relu, tb)
        } else {
            gb.unary("score", UnKind::Sigmoid, tb)
        };
        in_dim = *out_dim;
    }
    gb.finish(&[h])
}

/// `seq` here is the sparse id-list length (the dynamism axis).
pub fn gen_inputs(seq: usize, rng: &mut Prng) -> Vec<Tensor> {
    vec![
        Tensor::f32(&[BATCH, DENSE], rng.fill_f32(BATCH * DENSE, 0.5)),
        Tensor::i64(&[seq], rng.fill_i64(seq, 0, VOCAB as i64 - 1)),
    ]
}

pub fn workload() -> Workload {
    Workload {
        name: "ad_ranking",
        framework: "TensorFlow",
        batch: BATCH,
        graph: graph(),
        seq_range: (32, 256),
        gen: Box::new(gen_inputs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};
    use crate::runtime::reference::eval_module;

    #[test]
    fn unique_drives_data_dependent_shapes_through_compiled_path() {
        let w = workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        assert!(m.instrs.iter().any(|i| matches!(i.op, crate::dhlo::Op::Unique)));
        let compiler = DiscCompiler::new().unwrap();
        let mut model = compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap();
        let mut rng = Prng::new(12);
        for seq in [32usize, 100] {
            let inputs = gen_inputs(seq, &mut rng);
            let got = model.run(&inputs).unwrap();
            let want = eval_module(model.module(), &inputs).unwrap();
            assert_eq!(got.outputs[0].dims, vec![BATCH, 1]);
            assert!(got.outputs[0].allclose(&want.outputs[0], 5e-4, 5e-4).unwrap());
            // Scores are probabilities.
            assert!(got.outputs[0].as_f32().unwrap().iter().all(|&s| (0.0..=1.0).contains(&s)));
        }
    }
}
