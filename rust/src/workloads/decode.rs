//! Autoregressive decode-step workload — the growing-sequence scenario the
//! paper's lineage (Nimble's loops, Relax's symbolic shapes) targets, and
//! the graph behind the serving stack's decode mode.
//!
//! One invocation computes ONE decode step for one request. Every input
//! arrives at the request's KV-slab **bucket capacity** `C` (see
//! `runtime/kv.rs`), so consecutive steps inside a bucket bind the same
//! symbol vector and replay one `LaunchPlan` family:
//!
//! * `x_hist  [C, H]` — embedding history; row `t` embeds token `t`.
//! * `aux     [C, 2]` — column 0: additive attention mask over past lanes
//!   (`0.0` valid, `-1e9` empty — exp underflow keeps padded softmax
//!   bit-exact); column 1: one-hot selector of the current row.
//! * `kv_slab_l [C, 2H]` per layer — keys in columns `0..H`, values in
//!   `H..2H`, appended in place by the step-loop driver.
//!
//! The step must stay **batch-eligible** (decode serving coalesces
//! same-capacity *and* mixed-capacity requests into stacked dispatches),
//! which shapes two choices: every parameter leads with the dynamic
//! capacity symbol, and column extraction uses exact 0/1 constant
//! projection GEMMs instead of `Split` — the dynamic-axis `Split` lowering
//! mints content-reading shape symbols (`DSlice` extents) that make a
//! program ineligible for batching. The projections are bit-exact (each
//! output element is `1.0 * x` plus exact zeros) and classify as Stacked
//! GEMMs, the row-parallel launches batching amortizes.
//!
//! This module is also the **shared decode driver**: the growing-time-axis
//! placeholder and decoder-cell helpers here are reused by the single-step
//! decoder workloads (`seq2seq`, `tts`) so the loop and the single-step
//! graphs share one definition of the time axis.

use super::Workload;
use crate::dhlo::{BinKind, DType, Literal, UnKind};
use crate::graph::{Edge, GOp, Graph, GraphBuilder};
use crate::runtime::kv::{DecodeSpec, MASK_NEG};
use crate::runtime::tensor::Tensor;
use crate::util::prng::Prng;

pub const HIDDEN: usize = 64;
pub const FFN: usize = 128;
pub const VOCAB: usize = 256;
pub const LAYERS: usize = 2;

// ---- shared decode-driver pieces (used by seq2seq / tts / decode) -------

/// The growing time axis: a dynamic-leading `[S, cols]` f32 placeholder.
/// Every decoder-step input that grows with the sequence (encoder memory,
/// embedding history, KV slabs) is declared through this one definition.
pub fn time_axis(gb: &mut GraphBuilder, name: &str, cols: usize) -> Edge {
    gb.placeholder(name, DType::F32, &[-1, cols as i64])
}

/// The growing time axis for token ids: a dynamic `[S]` i64 placeholder.
pub fn time_axis_ids(gb: &mut GraphBuilder, name: &str) -> Edge {
    gb.placeholder(name, DType::I64, &[-1])
}

/// Gated decoder cell core: `{prefix}z = sigmoid(z_in)` and
/// `{prefix}cand = tanh(cand_in)` — the sigmoid/tanh pair every decoder
/// step (seq2seq's GRU-ish cell, tts's gated update) builds on.
pub fn gate_pair(
    gb: &mut GraphBuilder,
    prefix: &str,
    z_in: Edge,
    cand_in: Edge,
) -> (Edge, Edge) {
    let z = gb.unary(&format!("{prefix}z"), UnKind::Sigmoid, z_in);
    let cand = gb.unary(&format!("{prefix}cand"), UnKind::Tanh, cand_in);
    (z, cand)
}

/// Additive-attention energies over a (dynamic) set of keys:
/// `tanh(keys + q_row) · v -> [S, 1]`, with the query row broadcast over
/// the time axis. Shared by tts's encoder-memory attention and the decode
/// step's KV-slab attention.
pub fn additive_energy(
    gb: &mut GraphBuilder,
    prefix: &str,
    keys: Edge,
    q_row: Edge,
    v: Edge,
) -> Edge {
    let added = gb.binary(&format!("{prefix}added"), BinKind::Add, keys, q_row);
    let th = gb.unary(&format!("{prefix}tanh"), UnKind::Tanh, added);
    gb.matmul(&format!("{prefix}scores"), th, v)
}

// ---- the decode-step graph ----------------------------------------------

/// Exact 0/1 constant `[rows, hi-lo]` projection selecting columns
/// `lo..hi` via GEMM. Bit-exact (`1.0 * x` plus exact zeros) and
/// batch-classified Stacked, unlike a dynamic-axis `Split`.
fn col_selector(gb: &mut GraphBuilder, name: &str, rows: usize, lo: usize, hi: usize) -> Edge {
    let cols = hi - lo;
    let mut data = vec![0.0f32; rows * cols];
    for r in lo..hi {
        data[r * cols + (r - lo)] = 1.0;
    }
    gb.add(name, GOp::Const { lit: Literal::F32(data), dims: vec![rows, cols] }, &[])
}

/// One decode layer: additive attention of the current token's query over
/// the layer's KV slab (masked past lanes) plus an in-graph self lane,
/// then out-projection, residual/LN, and FFN. Returns the layer output
/// `[1, H]` and the packed `[1, 2H]` KV row to append.
fn decode_layer(
    gb: &mut GraphBuilder,
    x: Edge,
    slab: Edge,
    mask_row: Edge,
    layer: usize,
    seed: u64,
) -> (Edge, Edge) {
    let p = |s: &str| format!("l{layer}_{s}");
    // Split the slab into its K and V halves ([C, H] each, Stacked).
    let pk = col_selector(gb, &p("proj_k"), 2 * HIDDEN, 0, HIDDEN);
    let pv = col_selector(gb, &p("proj_v"), 2 * HIDDEN, HIDDEN, 2 * HIDDEN);
    let k_slab = gb.matmul(&p("k_slab"), slab, pk);
    let v_slab = gb.matmul(&p("v_slab"), slab, pv);

    // Current-token projections [1, H].
    let wq = gb.weight(&p("wq"), &[HIDDEN, HIDDEN], seed + 1);
    let wk = gb.weight(&p("wk"), &[HIDDEN, HIDDEN], seed + 2);
    let wv = gb.weight(&p("wv"), &[HIDDEN, HIDDEN], seed + 3);
    let q = gb.matmul(&p("q"), x, wq);
    let k_new = gb.matmul(&p("k_new"), x, wk);
    let v_new = gb.matmul(&p("v_new"), x, wv);

    // Additive attention energies over the slab's past lanes (the [C, H]
    // keys GEMM is the dominant, batching-amortized launch) ...
    let wm = gb.weight(&p("attn_wm"), &[HIDDEN, HIDDEN], seed + 4);
    let va = gb.weight(&p("attn_v"), &[HIDDEN, 1], seed + 5);
    let keys = gb.matmul(&p("attn_keys"), k_slab, wm);
    let q_row = gb.reshape(&p("attn_q_row"), q, &[HIDDEN as i64]);
    let e_past = additive_energy(gb, &p("attn_past_"), keys, q_row, va); // [C, 1]
    let e_past_t = gb.transpose(&p("attn_past_t"), e_past, &[1, 0]); // [1, C]
    // ... masked additively: empty lanes get -1e9 and underflow to an
    // exact 0.0 softmax weight. (This Add also unifies the slab's leading
    // symbol with aux's — one shared capacity symbol across parameters.)
    let e_masked = gb.binary(&p("attn_masked"), BinKind::Add, e_past_t, mask_row);
    // ... plus the in-graph self lane (k/v of the current token are not in
    // the slab yet; they are appended after the step).
    let keys_self = gb.matmul(&p("attn_keys_self"), k_new, wm); // [1, H]
    let e_self = additive_energy(gb, &p("attn_self_"), keys_self, q_row, va); // [1, 1]
    let scores = gb.concat(&p("attn_scores"), &[e_masked, e_self], 1); // [1, C+1]
    let attn = gb.softmax(&p("attn_weights"), scores);
    let v_full = gb.concat(&p("v_full"), &[v_slab, v_new], 0); // [C+1, H]
    let ctx = gb.matmul(&p("attn_ctx"), attn, v_full); // [1, H]

    // Out-projection, residual + LN, FFN — the transformer block tail.
    let wo = gb.weight(&p("wo"), &[HIDDEN, HIDDEN], seed + 6);
    let proj = gb.matmul(&p("proj"), ctx, wo);
    let res1 = gb.binary(&p("res1"), BinKind::Add, x, proj);
    let g1 = gb.weight(&p("g1"), &[HIDDEN], seed + 7);
    let b1 = gb.weight(&p("b1"), &[HIDDEN], seed + 8);
    let ln1 = gb.layernorm(&p("ln1"), res1, g1, b1);
    let w1 = gb.weight(&p("w1"), &[HIDDEN, FFN], seed + 9);
    let w2 = gb.weight(&p("w2"), &[FFN, HIDDEN], seed + 10);
    let bias1 = gb.weight(&p("bias1"), &[FFN], seed + 11);
    let bias2 = gb.weight(&p("bias2"), &[HIDDEN], seed + 12);
    let h1 = gb.matmul(&p("ff1"), ln1, w1);
    let h1b = gb.bias_add(&p("ff1b"), h1, bias1);
    let act = gb.unary(&p("act"), UnKind::Gelu, h1b);
    let h2 = gb.matmul(&p("ff2"), act, w2);
    let h2b = gb.bias_add(&p("ff2b"), h2, bias2);
    let res2 = gb.binary(&p("res2"), BinKind::Add, ln1, h2b);
    let g2 = gb.weight(&p("g2"), &[HIDDEN], seed + 13);
    let b2 = gb.weight(&p("b2"), &[HIDDEN], seed + 14);
    let out = gb.layernorm(&p("ln2"), res2, g2, b2);

    let kv_row = gb.concat(&p("kv_new"), &[k_new, v_new], 1); // [1, 2H]
    (out, kv_row)
}

pub fn graph() -> Graph {
    let mut gb = GraphBuilder::new("decode");
    let x_hist = time_axis(&mut gb, "x_hist", HIDDEN);
    let aux = time_axis(&mut gb, "aux", 2);
    let slabs: Vec<Edge> =
        (0..LAYERS).map(|l| time_axis(&mut gb, &format!("kv_slab_{l}"), 2 * HIDDEN)).collect();

    // Column extraction from aux: the additive mask row and the one-hot
    // current-row selector, both [1, C].
    let p_mask = col_selector(&mut gb, "proj_mask", 2, 0, 1);
    let p_sel = col_selector(&mut gb, "proj_sel", 2, 1, 2);
    let mask_col = gb.matmul("mask_col", aux, p_mask);
    let sel_col = gb.matmul("sel_col", aux, p_sel);
    let mask_row = gb.transpose("mask_row", mask_col, &[1, 0]);
    let sel_row = gb.transpose("sel_row", sel_col, &[1, 0]);
    // Current-token embedding: exact one-hot row selection from the
    // history (also ties x_hist's capacity symbol to aux's).
    let mut x = gb.matmul("x_cur", sel_row, x_hist); // [1, H]

    let mut kv_new = Vec::with_capacity(LAYERS);
    for (l, &slab) in slabs.iter().enumerate() {
        let (out, kv_row) = decode_layer(&mut gb, x, slab, mask_row, l, 3000 + 100 * l as u64);
        x = out;
        kv_new.push(kv_row);
    }

    // Vocabulary head.
    let wo = gb.weight("head_w", &[HIDDEN, VOCAB], 3900);
    let bo = gb.weight("head_b", &[VOCAB], 3901);
    let logits = gb.matmul("logits", x, wo);
    let logits_b = gb.bias_add("logits_b", logits, bo);
    let probs = gb.softmax("probs", logits_b); // [1, V]

    let mut outs = vec![probs];
    outs.extend(kv_new);
    gb.finish(&outs)
}

/// Deterministic host-side token embedding (the decode analogue of the
/// other workloads' embedded constant tables).
pub fn embed(token: i64, hidden: usize) -> Vec<f32> {
    let mut rng = Prng::new(0x9e37_79b9_7f4a_7c15 ^ (token as u64).wrapping_mul(0x100_0000_01b3));
    rng.fill_f32(hidden, 0.5)
}

/// The runtime description of this graph for the decode drivers.
pub fn spec() -> DecodeSpec {
    DecodeSpec { layers: LAYERS, hidden: HIDDEN, vocab: VOCAB, embed }
}

/// One plausible mid-decode binding at capacity `seq`: `seq - 1` appended
/// past lanes, current row at the last lane. Lets the generic workload
/// machinery (request streams, mode sweeps) exercise the step graph
/// without driving a whole loop.
pub fn gen_inputs(seq: usize, rng: &mut Prng) -> Vec<Tensor> {
    let c = seq.max(1);
    let used = c - 1;
    let mut aux = vec![0.0f32; c * 2];
    for lane in 0..c {
        aux[lane * 2] = if lane < used { 0.0 } else { MASK_NEG };
        aux[lane * 2 + 1] = if lane == used { 1.0 } else { 0.0 };
    }
    let mut inputs = vec![
        Tensor::f32(&[c, HIDDEN], rng.fill_f32(c * HIDDEN, 0.5)),
        Tensor::f32(&[c, 2], aux),
    ];
    for _ in 0..LAYERS {
        inputs.push(Tensor::f32(&[c, 2 * HIDDEN], rng.fill_f32(c * 2 * HIDDEN, 0.5)));
    }
    inputs
}

pub fn workload() -> Workload {
    Workload {
        name: "decode",
        framework: "serving",
        batch: 1,
        graph: graph(),
        seq_range: (16, 96),
        gen: Box::new(gen_inputs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{CompileOptions, DiscCompiler, Mode};
    use crate::runtime::reference::eval_module;

    #[test]
    fn decode_step_compiles_and_matches_reference() {
        let w = workload();
        let m = crate::bridge::lower(&w.graph).unwrap();
        let compiler = DiscCompiler::new().unwrap();
        let mut model = compiler.compile(m, &CompileOptions::mode(Mode::Disc)).unwrap();
        let mut rng = Prng::new(4);
        for cap in [16usize, 32] {
            let inputs = gen_inputs(cap, &mut rng);
            let got = model.run(&inputs).unwrap();
            let want = eval_module(model.module(), &inputs).unwrap();
            assert_eq!(got.outputs[0].dims, vec![1, VOCAB]);
            assert_eq!(got.outputs[1].dims, vec![1, 2 * HIDDEN]);
            assert_eq!(got.outputs.len(), 1 + LAYERS);
            for (g, r) in got.outputs.iter().zip(&want.outputs) {
                assert!(g.allclose(r, 5e-4, 5e-4).unwrap(), "cap {cap}");
            }
            // Probabilities sum to ~1.
            let row: f32 = got.outputs[0].as_f32().unwrap().iter().sum();
            assert!((row - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn decode_step_is_batch_eligible_with_stacked_launches() {
        // Continuous batching rides the cross-request machinery: the step
        // graph must classify as batchable with stacked launches (the
        // projection-GEMM design exists exactly for this — a dynamic-axis
        // Split would poison eligibility with content-reading shape math).
        let m = crate::bridge::lower(&graph()).unwrap();
        let m = crate::passes::optimize(&m).unwrap();
        let p = crate::fusion::plan(&m, &crate::fusion::FusionOptions::default());
        let prog = crate::program::generate(m, &p).unwrap();
        let analysis = crate::runtime::batching::analyze(&prog);
        assert!(analysis.eligible(), "ineligible: {:?}", analysis.reason);
        assert!(analysis.stacked_steps >= 1, "no stacked launches to amortize");
    }

    #[test]
    fn masked_lanes_get_exactly_zero_attention() {
        // The bit-exactness keystone: -1e9 masked energies must underflow
        // to an exact 0.0 softmax weight, so a padded-capacity step equals
        // the exact-length computation bitwise.
        let m = crate::bridge::lower(&graph()).unwrap();
        let mut rng = Prng::new(9);
        let inputs = gen_inputs(16, &mut rng);
        let r = eval_module(&m, &inputs).unwrap();
        assert!(!r.outputs.is_empty());
        let x = (MASK_NEG - 1.0f32).exp();
        assert_eq!(x, 0.0, "mask energies must underflow exactly");
    }
}
