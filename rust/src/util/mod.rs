//! Small self-contained utilities built in-repo because the build is fully
//! offline (vendored crates only): a minimal JSON parser/serializer, a
//! deterministic PRNG, and an id-arena newtype helper.

pub mod json;
pub mod prng;

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a process-shared mutex, recovering from poisoning. A panicking
/// worker (or an injected chaos panic) unwinding while it holds a shared
/// lock must not cascade into every sibling's lookups: the states guarded
/// this way (kernel-store shards, device stats, queue receivers, the
/// weight table) are all consistent at mutation granularity, so the
/// poison flag carries no information worth honoring. Every shared lock
/// site in the serving path goes through here — a bare `.unwrap()` on any
/// of them would let one supervised panic wedge the whole pool, defeating
/// the coordinator's restart story.
pub fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Round `n` up to the next power of two (min 1). Used by the bucketing
/// scheme in codegen: dynamic dimensions are rounded up so that a small
/// family of compiled kernel variants covers every runtime shape.
pub fn next_pow2(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

/// Round `n` up to a multiple of `m` (m > 0).
pub fn round_up(n: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    n.div_ceil(m) * m
}

/// Human-readable byte count, used in logs and bench reports.
pub fn fmt_bytes(b: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", b, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pow2_rounding() {
        assert_eq!(next_pow2(0), 1);
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1000), 1024);
        assert_eq!(next_pow2(1024), 1024);
    }

    #[test]
    fn round_up_multiples() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn relock_recovers_a_poisoned_mutex() {
        let m = std::sync::Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned(), "the panicking holder must poison the mutex");
        assert_eq!(*relock(&m), 7, "relock serves the state regardless");
        *relock(&m) += 1;
        assert_eq!(*relock(&m), 8);
    }

    #[test]
    fn bytes_format() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }
}
