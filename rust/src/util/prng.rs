//! Deterministic PRNG (SplitMix64 + xoshiro-style mixing) used by workload
//! generators, property tests and synthetic data. No external crates: the
//! vendored registry has no `rand`, and determinism across runs matters more
//! than statistical strength here.

/// SplitMix64 generator. Deterministic for a given seed; passes basic
/// equidistribution sanity checks (see tests).
#[derive(Debug, Clone)]
pub struct Prng {
    state: u64,
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        Prng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in `[-s, s)`.
    pub fn f32_sym(&mut self, s: f32) -> f32 {
        (self.f32() * 2.0 - 1.0) * s
    }

    /// Approximately normal(0, 1) via the sum of 4 uniforms (Irwin–Hall).
    /// Good enough for synthetic activations/weights.
    pub fn normal(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.f32()).sum();
        (s - 2.0) * (12.0f32 / 4.0).sqrt()
    }

    /// Fill a tensor-sized buffer with small-magnitude values; scale keeps
    /// deep fused chains (exp, tanh) inside well-conditioned ranges so that
    /// reference-vs-compiled comparisons stay within tight tolerances.
    pub fn fill_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_sym(scale)).collect()
    }

    pub fn fill_i64(&mut self, n: usize, lo: i64, hi: i64) -> Vec<i64> {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        (0..n).map(|_| lo + (self.next_u64() % span) as i64).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u64() as f64 / u64::MAX as f64) < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(7);
        let mut b = Prng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut p = Prng::new(3);
        for _ in 0..1000 {
            assert!(p.below(7) < 7);
            let r = p.range(10, 20);
            assert!((10..=20).contains(&r));
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut p = Prng::new(9);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let v = p.f32();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn chance_rates() {
        let mut p = Prng::new(11);
        let hits = (0..10_000).filter(|_| p.chance(0.25)).count();
        assert!((2000..3000).contains(&hits), "hits={hits}");
    }
}
