//! Minimal JSON parser and serializer.
//!
//! The computation-graph frontend (`graph::import`) exchanges graphs with the
//! Python bridges as JSON. The build is offline-only, so instead of serde we
//! carry a small, well-tested JSON implementation: a recursive-descent parser
//! and a pretty-printer over a `Value` enum. It supports the full JSON
//! grammar except `\u` surrogate pairs beyond the BMP (sufficient for graph
//! files, which are ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization is
/// deterministic, which the golden-file tests rely on.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Field access for objects; returns `Null` for missing keys so chained
    /// lookups stay ergonomic.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        match self {
            Value::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn from_strs(items: &[&str]) -> Value {
        Value::Arr(items.iter().map(|s| Value::Str(s.to_string())).collect())
    }

    /// Build an object from `(key, value)` pairs (report/bench emission).
    pub fn obj(fields: Vec<(&str, Value)>) -> Value {
        Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_usizes(items: &[usize]) -> Value {
        Value::Arr(items.iter().map(|&u| Value::Num(u as f64)).collect())
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        Err(ParseError { pos: self.pos, msg: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|c| (c as char).to_digit(16))
                                .ok_or_else(|| ParseError {
                                    pos: self.pos,
                                    msg: "bad \\u escape".into(),
                                })?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x20 => return self.err("control char in string"),
                Some(c) => {
                    // Re-assemble UTF-8 multi-byte sequences byte-by-byte.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    match std::str::from_utf8(&self.bytes[start..self.pos.min(self.bytes.len())]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
                None => return self.err("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        match text.parse::<f64>() {
            Ok(n) => Ok(Value::Num(n)),
            Err(_) => self.err(format!("bad number '{text}'")),
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage is
/// an error.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    let pad = |n: usize, out: &mut String| out.push_str(&"  ".repeat(n));
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9.0e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => escape(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                out.push('\n');
                pad(indent + 1, out);
                write_value(item, indent + 1, out);
                if i + 1 < items.len() {
                    out.push(',');
                }
            }
            out.push('\n');
            pad(indent, out);
            out.push(']');
        }
        Value::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                out.push('\n');
                pad(indent + 1, out);
                escape(k, out);
                out.push_str(": ");
                write_value(val, indent + 1, out);
                if i + 1 < map.len() {
                    out.push(',');
                }
            }
            out.push('\n');
            pad(indent, out);
            out.push('}');
        }
    }
}

/// Serialize with two-space indentation (deterministic key order).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, 0, &mut out);
    out
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&to_string_pretty(self))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Value::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123abc").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Aé""#).unwrap(), Value::Str("Aé".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"graph": {"ops": [{"kind": "Add", "inputs": ["x", "y"]}], "n": 3.25}}"#;
        let v = parse(src).unwrap();
        let text = to_string_pretty(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → wörld");
    }
}
