//! `disc` — the CLI entrypoint: run workloads under any execution mode,
//! inspect lowered DHLO + collected constraints, import JSON graphs.

use anyhow::{bail, Context, Result};
use disc::cli::{parse_mode, Args, USAGE};
use disc::compiler::{CompileOptions, DiscCompiler};
use disc::coordinator;
use disc::sim::GpuModel;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return;
    }
    if let Err(e) = dispatch(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "run" => cmd_run(&args),
        "inspect" => cmd_inspect(&args),
        "import" => cmd_import(&args),
        "list" => {
            for name in disc::workloads::NAMES {
                let w = disc::workloads::by_name(name).unwrap();
                println!("{name:14} {:<12} batch={}", w.framework, w.batch);
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn load_workload(args: &Args) -> Result<disc::workloads::Workload> {
    let name = args.get("workload").context("--workload required")?;
    disc::workloads::by_name(name)
        .with_context(|| format!("unknown workload '{name}' (try: disc list)"))
}

fn cmd_run(args: &Args) -> Result<()> {
    if args.positional.first().map(|s| s.as_str()) == Some("mix")
        || args.get("workload") == Some("mix")
    {
        return cmd_run_mix(args);
    }
    let w = load_workload(args)?;
    if w.name == "decode" {
        return cmd_run_decode(args);
    }
    let mode = parse_mode(args.get("mode").unwrap_or("disc"))?;
    let requests = args.get_usize("requests", 50)?;
    let seed = args.get_usize("seed", 1)? as u64;
    let workers = args.get_usize("workers", 1)?;
    let burst = args.get_usize("burst", 0)?;
    let max_batch = args.get_usize("batch", 1)?;
    let batch_window_us = args.get_usize("batch-window-us", 0)? as u64;
    let deadline_ms = args.get_usize("deadline-ms", 0)? as u64;
    let rebucket_ms = args.get_usize("rebucket-interval", 0)? as u64;
    let max_buckets = args.get_usize("max-buckets", 8)?;

    let module = disc::bridge::lower(&w.graph)?;
    let compiler = DiscCompiler::new()?;
    let mut opts = CompileOptions::mode(mode);
    // Serving with workers wants compilation off the hot path: warm the
    // neighbor buckets speculatively while recording.
    opts.runtime.speculative_warm = args.get_bool("warm");
    if args.get_bool("no-memplan") {
        opts.runtime.memory_plan = false;
    }
    let mut model = compiler.compile(module, &opts)?;
    println!(
        "compiled {} [{}] pipeline={} groups={} kernels-planned={} ({} instrs)",
        w.name,
        w.framework,
        model.report.pipeline,
        model.report.fusion_groups,
        model.report.planned_kernels,
        model.report.instrs_after,
    );

    let stream = w.request_stream(requests, seed);
    let report = match args.get("open-rate") {
        Some(r) => {
            let rate: f64 = r.parse().context("--open-rate wants a float")?;
            let mut sopts = coordinator::ServeOptions::rate(rate)
                .workers(workers)
                .batch(max_batch)
                .batch_window_us(batch_window_us)
                .rebucket_every_ms(rebucket_ms)
                .max_buckets(max_buckets);
            if burst > 0 {
                sopts = sopts.bursty(burst);
            }
            if deadline_ms > 0 {
                sopts = sopts.deadline_ms(deadline_ms);
            }
            if let Some(spec) = args.get("faults") {
                sopts = sopts.faults(std::sync::Arc::new(
                    disc::runtime::faults::FaultPlan::parse(spec).context("--faults spec")?,
                ));
            }
            coordinator::serve_open_loop(&mut model, stream, &sopts)?
        }
        None => coordinator::serve_closed_loop(&mut model, stream)?,
    };

    let sim = GpuModel::default().breakdown(&report.metrics);
    println!(
        "served {} requests in {:.2?}  ({:.1} req/s)",
        report.completed, report.wall, report.throughput_rps
    );
    println!(
        "latency p50={:.2?} p95={:.2?} p99={:.2?} mean={:.2?}",
        report.p50, report.p95, report.p99, report.mean
    );
    let m = &report.metrics;
    println!(
        "kernels: mem={} lib={} host_ops={} compile_events={} (compile {:.2?}, stall {:.2?}, dedup_hits={})",
        m.mem_kernels,
        m.lib_calls,
        m.host_ops,
        m.compile_events,
        m.compile_time,
        m.compile_stall,
        m.compile_dedup_hits
    );
    println!(
        "time split: kernel={:.2?} lib={:.2?} cpu={:.2?} total={:.2?} (pad_copies={} allocs={} pool_hits={})",
        m.kernel_time, m.lib_time, m.cpu_time(), m.total_time, m.pad_copies, m.allocs, m.pool_hits
    );
    println!(
        "bytes: mem={} lib={}  flops={}",
        disc::util::fmt_bytes(m.mem_bytes as usize),
        disc::util::fmt_bytes(m.lib_bytes as usize),
        m.flops
    );
    println!(
        "plans: hits={} misses={} guard_misses={}  transfers: h2d={} d2h={}  resident-peak={}",
        m.plan_hits,
        m.plan_misses,
        m.plan_guard_misses,
        disc::util::fmt_bytes(m.h2d_bytes as usize),
        disc::util::fmt_bytes(m.d2h_bytes as usize),
        disc::util::fmt_bytes(m.device_resident_bytes as usize)
    );
    if m.planned_peak_bytes > 0 {
        println!(
            "memory plan: planned-peak={} reuse-saved={}",
            disc::util::fmt_bytes(m.planned_peak_bytes as usize),
            disc::util::fmt_bytes(m.mem_plan_reuse_bytes as usize)
        );
    }
    println!(
        "weight cache: hits={} misses={} resident={}",
        m.weight_cache_hits,
        m.weight_cache_misses,
        disc::util::fmt_bytes(m.weight_resident_bytes as usize)
    );
    println!(
        "batching: dispatches={} occupancy={:.2} batched_requests={} batched_launches={} \
         padding-waste={} stack-copies={}",
        report.batch_launches,
        report.batch_occupancy,
        m.batched_requests,
        m.batched_launches,
        disc::util::fmt_bytes(m.batch_padding_bytes as usize),
        disc::util::fmt_bytes(m.batch_stack_bytes as usize)
    );
    println!(
        "batch plans: hits={} misses={} guard_misses={}  dev-resident-peak={}",
        m.batch_plan_hits,
        m.batch_plan_misses,
        m.batch_plan_guard_misses,
        disc::util::fmt_bytes(m.batch_dev_resident_bytes as usize)
    );
    println!(
        "bucketing: epoch={} swaps={} padded_elems={} launch_elems={} padding_ratio={:.4} \
         hist_syms={}",
        m.policy_epoch,
        m.rebucket_swaps,
        m.padded_elems,
        m.launch_elems,
        m.padding_ratio(),
        m.extent_hist.len()
    );
    println!(
        "robustness: shed={} deadline_misses={} retries={} demotions={} worker_restarts={}",
        m.shed_requests, m.deadline_misses, m.retries, m.demotions, m.worker_restarts
    );
    if report.per_worker.len() > 1 {
        println!(
            "queue delay: p50={:.2?} p99={:.2?}  ({} workers)",
            report.queue_p50,
            report.queue_p99,
            report.per_worker.len()
        );
        for wr in &report.per_worker {
            println!(
                "  worker {}: {} reqs / {} dispatches  mean={:.2?} p99={:.2?}  \
                 plans h/m={}/{}  compiles={}",
                wr.worker,
                wr.completed,
                wr.launches,
                wr.mean,
                wr.p99,
                wr.metrics.plan_hits,
                wr.metrics.plan_misses,
                wr.metrics.compile_events
            );
        }
        let snap = compiler.kernel_store().snapshot();
        println!(
            "kernel store: entries={} compiles={} hits={} dedup={} prefetched={} (stall {:.2?})",
            snap.entries, snap.misses, snap.hits, snap.dedup_hits, snap.prefetches, snap.stall
        );
    }
    println!(
        "T4-model breakdown: comp={:.2}ms mem={:.2}ms cpu={:.2}ms e2e={:.2}ms",
        sim.comp_bound_ms, sim.mem_bound_ms, sim.cpu_ms, sim.e2e_ms
    );
    if let Some(cs) = model.cache_stats() {
        println!(
            "kernel cache: entries={} hits={} misses={} compile={:.2?}",
            cs.entries, cs.hits, cs.misses, cs.compile_time
        );
    }
    if let Some(ps) = model.plan_stats() {
        println!(
            "plan cache: entries={} hits={} misses={} guard_misses={}",
            ps.entries, ps.hits, ps.misses, ps.guard_misses
        );
    }
    if let Some(bs) = model.batch_plan_stats() {
        println!(
            "batch plan cache: entries={} hits={} misses={} guard_misses={}",
            bs.entries, bs.hits, bs.misses, bs.guard_misses
        );
    }
    Ok(())
}

/// Autoregressive decode serving: jobs step through the model one token
/// at a time with iteration-level (continuous) batching, their KV caches
/// living in the executor arena as bucket-sized slabs.
fn cmd_run_decode(args: &Args) -> Result<()> {
    let mode = parse_mode(args.get("mode").unwrap_or("disc"))?;
    let requests = args.get_usize("requests", 8)?;
    let seed = args.get_usize("seed", 1)? as u64;
    let prompt_len = args.get_usize("prompt-len", 4)?.max(1);
    let gen_steps = args.get_usize("gen-steps", 24)?;
    let max_batch = args.get_usize("batch", 4)?;
    let stagger = args.get_usize("stagger", 2)? as u64;
    let deadline_ms = args.get_usize("deadline-ms", 0)? as u64;
    let rebucket_ms = args.get_usize("rebucket-interval", 0)? as u64;
    let max_buckets = args.get_usize("max-buckets", 8)?;

    let graph = disc::workloads::decode::graph();
    let module = disc::bridge::lower(&graph)?;
    let compiler = DiscCompiler::new()?;
    let mut model = compiler.compile(module, &CompileOptions::mode(mode))?;
    println!(
        "compiled decode [serving] pipeline={} groups={} kernels-planned={} ({} instrs)",
        model.report.pipeline,
        model.report.fusion_groups,
        model.report.planned_kernels,
        model.report.instrs_after,
    );

    let spec = disc::workloads::decode::spec();
    let mut rng = disc::util::prng::Prng::new(seed);
    let vocab = disc::workloads::decode::VOCAB as i64;
    let jobs: Vec<coordinator::decode::DecodeJob> = (0..requests)
        .map(|i| coordinator::decode::DecodeJob {
            id: i as u64,
            prompt: rng.fill_i64(prompt_len, 0, vocab - 1),
            gen_steps,
            arrive_step: i as u64 * stagger,
        })
        .collect();
    let mut dopts = coordinator::decode::DecodeServeOptions::batch(max_batch)
        .rebucket_every_ms(rebucket_ms)
        .max_buckets(max_buckets);
    if deadline_ms > 0 {
        dopts = dopts.deadline(std::time::Duration::from_millis(deadline_ms));
    }
    if let Some(spec_str) = args.get("faults") {
        dopts = dopts.faults(std::sync::Arc::new(
            disc::runtime::faults::FaultPlan::parse(spec_str).context("--faults spec")?,
        ));
    }
    let report = coordinator::decode::serve_decode(&mut model, &spec, jobs, &dopts)?;

    let m = &report.metrics;
    println!(
        "decoded {}/{} jobs in {:.2?}  {} steps ({:.1} tok/s)",
        report.completed.len(),
        report.offered,
        report.wall,
        report.total_steps,
        report.tokens_per_sec,
    );
    println!(
        "scheduling: dispatches={} batched={} max-occupancy={} mid-flight-joins={}",
        report.dispatches, report.batched_dispatches, report.max_occupancy, report.joins,
    );
    println!(
        "kv: rollovers={} resident-peak={}  plans: hits={} misses={} guard_misses={}",
        m.kv_rollovers,
        disc::util::fmt_bytes(m.kv_resident_bytes as usize),
        m.plan_hits,
        m.plan_misses,
        m.plan_guard_misses,
    );
    println!(
        "bucketing: epoch={} swaps={} padded_elems={} launch_elems={} padding_ratio={:.4}",
        m.policy_epoch,
        m.rebucket_swaps,
        m.padded_elems,
        m.launch_elems,
        m.padding_ratio(),
    );
    println!(
        "robustness: shed={} deadline_misses={} demotions={} worker_restarts={}",
        m.shed_requests, m.deadline_misses, m.demotions, m.worker_restarts
    );
    Ok(())
}

/// Parse the `--tenants` list: `name:workload[:slo[:weight[:floor-mb]]]`
/// entries separated by commas. Shared flags (`--requests`, `--rate`,
/// `--deadline-ms`, `--seed`, `--fault-tenant`) refine every entry.
fn parse_tenants(
    spec: &str,
    args: &Args,
) -> Result<Vec<disc::coordinator::tenants::TenantSpec>> {
    use disc::coordinator::tenants::TenantSpec;
    let requests = args.get_usize("requests", 0)?;
    let rate: Option<f64> = match args.get("rate") {
        Some(r) => Some(r.parse().context("--rate wants a float")?),
        None => None,
    };
    let deadline_ms = args.get_usize("deadline-ms", 0)? as u64;
    let seed = args.get_usize("seed", 1)? as u64;
    let fault_tenant = args.get("fault-tenant");
    let mut out = Vec::new();
    for (i, item) in spec.split(',').filter(|s| !s.is_empty()).enumerate() {
        let mut parts = item.split(':');
        let name = parts.next().unwrap_or_default();
        if name.is_empty() {
            bail!("--tenants entry '{item}' is missing a name");
        }
        let workload = parts.next().unwrap_or(name);
        let slo = parts.next().unwrap_or(if i == 0 { "latency" } else { "throughput" });
        let mut t = match slo {
            "latency" | "lat" => TenantSpec::latency(name, workload),
            "throughput" | "thr" => TenantSpec::throughput(name, workload),
            other => bail!("tenant '{name}': unknown slo '{other}' (latency|throughput)"),
        };
        if let Some(w) = parts.next() {
            t = t.weight(w.parse().with_context(|| format!("tenant '{name}': weight"))?);
        }
        if let Some(mb) = parts.next() {
            let mb: u64 =
                mb.parse().with_context(|| format!("tenant '{name}': floor-mb"))?;
            t = t.floor_bytes(mb << 20);
        }
        if requests > 0 {
            t = t.requests(requests);
        }
        if let Some(r) = rate {
            t = t.rate(r);
        }
        if deadline_ms > 0 {
            t = t.deadline_ms(deadline_ms);
        }
        // Distinct deterministic stream per tenant off the shared base seed.
        t = t.seed(seed.wrapping_add(i as u64));
        if fault_tenant == Some(name) {
            t = t.fault_target();
        }
        out.push(t);
    }
    if out.is_empty() {
        bail!("--tenants wants at least one name:workload entry");
    }
    if let Some(ft) = fault_tenant {
        if !out.iter().any(|t| t.name == ft) {
            bail!("--fault-tenant '{ft}' does not name a tenant");
        }
    }
    Ok(out)
}

/// Multi-tenant serving: N models behind one admission front with
/// per-tenant bulkheads (own queue, SLO class, fair-share weight,
/// weight-cache floor) and per-tenant circuit breakers.
fn cmd_run_mix(args: &Args) -> Result<()> {
    use disc::coordinator::tenants::{serve_mix, MixOptions, Quarantine};
    let tenants_spec = args
        .get("tenants")
        .unwrap_or("lat:transformer:latency,bert:bert:throughput,tts:tts:throughput");
    let specs = parse_tenants(tenants_spec, args)?;
    let mut opts = MixOptions::new()
        .workers(args.get_usize("workers", 2)?)
        .batch(args.get_usize("batch", 4)?)
        .rebucket_every_ms(args.get_usize("rebucket-interval", 0)? as u64)
        .max_buckets(args.get_usize("max-buckets", 8)?)
        .breaker(
            args.get_usize("breaker", 3)? as u32,
            args.get_usize("probe-after", 8)? as u64,
        );
    match args.get("quarantine") {
        None | Some("reference") => {}
        Some("shed") => opts = opts.quarantine(Quarantine::Shed),
        Some(other) => bail!("--quarantine wants reference|shed, got '{other}'"),
    }
    if let Some(spec) = args.get("faults") {
        opts = opts.faults(std::sync::Arc::new(
            disc::runtime::faults::FaultPlan::parse(spec).context("--faults spec")?,
        ));
    }
    let budget_mb = args.get_usize("weight-budget-mb", 0)? as u64;
    if budget_mb > 0 {
        opts = opts.weight_budget(budget_mb << 20);
    }

    let report = serve_mix(specs, &opts)?;
    println!("mix: served {} tenants in {:.2?}", report.tenants.len(), report.wall);
    for t in &report.tenants {
        let m = &t.report.metrics;
        println!(
            "tenant {:<10} [{:<10}] completed {}/{}  p50={:.2?} p99={:.2?}  ({:.1} req/s)",
            t.name,
            t.slo.as_str(),
            t.report.completed,
            t.offered,
            t.report.p50,
            t.report.p99,
            t.report.throughput_rps
        );
        println!(
            "  robustness: shed={} deadline_misses={} demotions={} worker_restarts={} \
             breaker_trips={} probes={} quarantined={}",
            m.shed_requests,
            m.deadline_misses,
            m.demotions,
            m.worker_restarts,
            t.breaker_trips,
            t.probes,
            m.quarantined
        );
        println!(
            "  service: dispatches={} plans h/m={}/{} compiles={} weight-resident={} \
             padding_ratio={:.4} epoch={}",
            t.report.batch_launches,
            m.plan_hits,
            m.plan_misses,
            m.compile_events,
            disc::util::fmt_bytes(m.weight_resident_bytes as usize),
            m.padding_ratio(),
            m.policy_epoch
        );
    }
    let a = &report.aggregate;
    println!(
        "aggregate: compile_events={} shed={} quarantined={} breaker_trips={} \
         weight cache h/m={}/{}",
        a.compile_events,
        a.shed_requests,
        a.quarantined,
        a.breaker_trips,
        a.weight_cache_hits,
        a.weight_cache_misses
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let module = if let Some(file) = args.get("file") {
        let text = std::fs::read_to_string(file)?;
        let g = disc::graph::import::from_json(&text)?;
        disc::bridge::lower(&g)?
    } else {
        let w = load_workload(args)?;
        disc::bridge::lower(&w.graph)?
    };
    let opt = disc::passes::optimize(&module)?;
    print!("{}", disc::dhlo::print::print_module(&opt));
    let plan = disc::fusion::plan(&opt, &disc::fusion::FusionOptions::default());
    let stats = disc::fusion::stats(&plan);
    println!(
        "// fusion: {} groups ({} input-fusions, largest {}), {} kernels planned",
        stats.groups,
        stats.input_fusions,
        stats.largest_group,
        plan.kernel_count(&opt)
    );
    let rep = disc::passes::static_detect::analyze(&opt);
    println!(
        "// pipeline: {:?} ({}/{} instrs dynamic)",
        rep.choice, rep.dynamic_instrs, rep.total_instrs
    );
    Ok(())
}

fn cmd_import(args: &Args) -> Result<()> {
    let file = args.get("file").context("--file required")?;
    let text = std::fs::read_to_string(file)?;
    let g = disc::graph::import::from_json(&text)?;
    println!("imported graph '{}' with {} nodes", g.name, g.nodes.len());
    let module = disc::bridge::lower(&g)?;
    let mode = parse_mode(args.get("mode").unwrap_or("disc"))?;
    let compiler = DiscCompiler::new()?;
    let model = compiler.compile(module, &CompileOptions::mode(mode))?;
    println!(
        "compiled: pipeline={} groups={} planned-kernels={}",
        model.report.pipeline, model.report.fusion_groups, model.report.planned_kernels
    );
    Ok(())
}
