//! JSON graph import/export — the multi-framework frontend surface (§4.4).
//!
//! Graphs arrive as JSON in either a TensorFlow-flavoured or a
//! PyTorch-flavoured op vocabulary; both alias onto the same [`GOp`] set,
//! with DHLO as the hub IR underneath — "this intermediate layer simplifies
//! the adaptation". Edges are `"node"` or `"node:port"` strings.

use crate::dhlo::{BinKind, CmpDir, DType, Literal, ReduceKind, UnKind};
use crate::graph::{Edge, GOp, Graph, Node};
use crate::util::json::{self, Value};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;

fn parse_dtype(s: &str) -> Result<DType> {
    Ok(match s {
        "f32" | "float32" | "float" | "torch.float32" => DType::F32,
        "i64" | "s64" | "int64" | "torch.int64" | "torch.long" => DType::I64,
        "i32" | "s32" | "int32" | "torch.int32" => DType::I32,
        "bool" | "pred" | "torch.bool" => DType::Pred,
        other => bail!("unknown dtype '{other}'"),
    })
}

/// Op-name aliases: TF names, PyTorch names, and the native names all map
/// onto the same framework op. (Attribute spellings are shared.)
fn parse_op(kind: &str, v: &Value) -> Result<GOp> {
    let axis = || v.get("axis").as_usize().unwrap_or(0);
    let axes = || -> Vec<usize> {
        v.get("axes")
            .as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default()
    };
    let i64s = |key: &str| -> Vec<i64> {
        v.get(key)
            .as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_i64()).collect())
            .unwrap_or_default()
    };
    let usizes = |key: &str| -> Vec<usize> {
        v.get(key)
            .as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
            .unwrap_or_default()
    };

    Ok(match kind {
        "Placeholder" | "torch.placeholder" | "input" => GOp::Placeholder {
            dtype: parse_dtype(v.get("dtype").as_str().unwrap_or("f32"))?,
            dims: i64s("dims"),
        },
        "Const" | "torch.tensor" => {
            let dims = usizes("dims");
            let dtype = parse_dtype(v.get("dtype").as_str().unwrap_or("f32"))?;
            let vals = v.get("values").as_arr().context("Const needs values")?;
            let lit = match dtype {
                DType::F32 => Literal::F32(
                    vals.iter().map(|x| x.as_f64().unwrap_or(0.0) as f32).collect(),
                ),
                DType::I64 => {
                    Literal::I64(vals.iter().map(|x| x.as_i64().unwrap_or(0)).collect())
                }
                DType::I32 => {
                    Literal::I32(vals.iter().map(|x| x.as_i64().unwrap_or(0) as i32).collect())
                }
                DType::Pred => {
                    Literal::Pred(vals.iter().map(|x| x.as_bool().unwrap_or(false)).collect())
                }
            };
            GOp::Const { lit, dims }
        }
        // Elementwise unary.
        "Tanh" | "torch.tanh" => GOp::Unary(UnKind::Tanh),
        "Exp" | "torch.exp" => GOp::Unary(UnKind::Exp),
        "Log" | "torch.log" => GOp::Unary(UnKind::Log),
        "Abs" | "torch.abs" => GOp::Unary(UnKind::Abs),
        "Neg" | "torch.neg" => GOp::Unary(UnKind::Neg),
        "Sqrt" | "torch.sqrt" => GOp::Unary(UnKind::Sqrt),
        "Rsqrt" | "torch.rsqrt" => GOp::Unary(UnKind::Rsqrt),
        "Relu" | "torch.relu" | "torch.nn.functional.relu" => GOp::Unary(UnKind::Relu),
        "Gelu" | "torch.nn.functional.gelu" => GOp::Unary(UnKind::Gelu),
        "Sigmoid" | "torch.sigmoid" => GOp::Unary(UnKind::Sigmoid),
        "Erf" | "torch.erf" => GOp::Unary(UnKind::Erf),
        "Floor" | "torch.floor" => GOp::Unary(UnKind::Floor),
        "Sign" | "torch.sign" => GOp::Unary(UnKind::Sign),
        // Elementwise binary.
        "Add" | "AddV2" | "torch.add" => GOp::Binary(BinKind::Add),
        "Sub" | "torch.sub" => GOp::Binary(BinKind::Sub),
        "Mul" | "torch.mul" => GOp::Binary(BinKind::Mul),
        "Div" | "RealDiv" | "torch.div" => GOp::Binary(BinKind::Div),
        "Maximum" | "torch.maximum" => GOp::Binary(BinKind::Max),
        "Minimum" | "torch.minimum" => GOp::Binary(BinKind::Min),
        "Pow" | "torch.pow" => GOp::Binary(BinKind::Pow),
        // Compare / select.
        "Greater" | "torch.gt" => GOp::Compare(CmpDir::Gt),
        "Less" | "torch.lt" => GOp::Compare(CmpDir::Lt),
        "Equal" | "torch.eq" => GOp::Compare(CmpDir::Eq),
        "Select" | "SelectV2" | "torch.where" => GOp::Select,
        "Cast" | "torch.to" => {
            GOp::Cast { to: parse_dtype(v.get("to").as_str().context("Cast needs 'to'")?)? }
        }
        "Scale" => GOp::Scale { c: v.get("c").as_f64().unwrap_or(1.0) as f32 },
        // Contractions & composites.
        "MatMul" | "BatchMatMul" | "BatchMatMulV2" | "torch.matmul" | "torch.bmm" => GOp::MatMul,
        "Softmax" | "torch.softmax" | "torch.nn.functional.softmax" => GOp::Softmax,
        "LayerNorm" | "torch.nn.functional.layer_norm" => {
            GOp::LayerNorm { eps: v.get("eps").as_f64().unwrap_or(1e-5) as f32 }
        }
        "BiasAdd" => GOp::BiasAdd,
        // Layout / shape.
        "Split" | "SplitV" | "torch.chunk" => GOp::Split {
            axis: axis(),
            num: v.get("num").as_usize().context("Split needs 'num'")?,
        },
        "Concat" | "ConcatV2" | "torch.cat" => GOp::Concat { axis: axis() },
        "Transpose" | "torch.permute" => GOp::Transpose { perm: usizes("perm") },
        "Reshape" | "torch.reshape" | "torch.view" => GOp::Reshape { dims: i64s("dims") },
        "Slice" | "torch.narrow" => GOp::Slice { begin: i64s("begin"), size: i64s("size") },
        "Pad" | "PadV2" | "torch.nn.functional.pad" => GOp::Pad {
            low: i64s("low"),
            high: i64s("high"),
            value: v.get("value").as_f64().unwrap_or(0.0) as f32,
        },
        // Reductions.
        "Sum" | "ReduceSum" | "torch.sum" => GOp::Reduce { kind: ReduceKind::Sum, axes: axes() },
        "Max" | "ReduceMax" | "torch.amax" => GOp::Reduce { kind: ReduceKind::Max, axes: axes() },
        "Mean" | "ReduceMean" | "torch.mean" => {
            GOp::Reduce { kind: ReduceKind::Mean, axes: axes() }
        }
        // Sparse / lookup.
        "GatherV2" | "Gather" | "torch.index_select" | "embedding_lookup" => {
            GOp::Gather { axis: axis() }
        }
        "Unique" | "torch.unique" => GOp::Unique,
        other => bail!("unknown op kind '{other}'"),
    })
}

fn parse_edge(s: &str, names: &HashMap<String, usize>) -> Result<Edge> {
    let (name, port) = match s.rsplit_once(':') {
        Some((n, p)) if !p.is_empty() && p.chars().all(|c| c.is_ascii_digit()) => {
            (n, p.parse::<usize>().unwrap())
        }
        _ => (s, 0),
    };
    let node = *names.get(name).with_context(|| format!("unknown node '{name}'"))?;
    Ok(Edge { node, port })
}

/// Parse a JSON graph document.
pub fn from_json(text: &str) -> Result<Graph> {
    let doc = json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
    let name = doc.get("name").as_str().unwrap_or("graph").to_string();
    let nodes_json = doc.get("nodes").as_arr().context("graph needs 'nodes'")?;
    let mut names: HashMap<String, usize> = HashMap::new();
    let mut nodes = Vec::with_capacity(nodes_json.len());

    for (i, nv) in nodes_json.iter().enumerate() {
        let nname = nv
            .get("name")
            .as_str()
            .map(|s| s.to_string())
            .unwrap_or_else(|| format!("n{i}"));
        let kind = nv.get("op").as_str().context("node needs 'op'")?;
        let op = parse_op(kind, nv).with_context(|| format!("node '{nname}'"))?;
        let inputs: Vec<Edge> = match nv.get("inputs").as_arr() {
            Some(arr) => arr
                .iter()
                .map(|e| parse_edge(e.as_str().context("input must be string")?, &names))
                .collect::<Result<_>>()?,
            None => vec![],
        };
        ensure!(!names.contains_key(&nname), "duplicate node name '{nname}'");
        names.insert(nname.clone(), i);
        nodes.push(Node { name: nname, op, inputs });
    }

    let outputs: Vec<Edge> = doc
        .get("outputs")
        .as_arr()
        .context("graph needs 'outputs'")?
        .iter()
        .map(|e| parse_edge(e.as_str().context("output must be string")?, &names))
        .collect::<Result<_>>()?;

    Ok(Graph { name, nodes, outputs })
}

/// Serialize a graph back to JSON (round-trip tested; used by `disc dump`).
pub fn to_json(g: &Graph) -> Value {
    use std::collections::BTreeMap;
    let mut root = BTreeMap::new();
    root.insert("name".into(), Value::Str(g.name.clone()));
    let nodes: Vec<Value> = g
        .nodes
        .iter()
        .map(|n| {
            let mut o = BTreeMap::new();
            o.insert("name".into(), Value::Str(n.name.clone()));
            let inputs: Vec<Value> = n
                .inputs
                .iter()
                .map(|e| {
                    let nm = &g.nodes[e.node].name;
                    Value::Str(if e.port == 0 {
                        nm.clone()
                    } else {
                        format!("{nm}:{}", e.port)
                    })
                })
                .collect();
            if !inputs.is_empty() {
                o.insert("inputs".into(), Value::Arr(inputs));
            }
            encode_op(&n.op, &mut o);
            Value::Obj(o)
        })
        .collect();
    root.insert("nodes".into(), Value::Arr(nodes));
    let outputs: Vec<Value> = g
        .outputs
        .iter()
        .map(|e| {
            let nm = &g.nodes[e.node].name;
            Value::Str(if e.port == 0 { nm.clone() } else { format!("{nm}:{}", e.port) })
        })
        .collect();
    root.insert("outputs".into(), Value::Arr(outputs));
    Value::Obj(root)
}

fn encode_op(op: &GOp, o: &mut std::collections::BTreeMap<String, Value>) {
    let put = |o: &mut std::collections::BTreeMap<String, Value>, k: &str, v: Value| {
        o.insert(k.to_string(), v);
    };
    match op {
        GOp::Placeholder { dtype, dims } => {
            put(o, "op", Value::Str("Placeholder".into()));
            put(o, "dtype", Value::Str(dtype.hlo_name().into()));
            put(o, "dims", Value::Arr(dims.iter().map(|&d| Value::Num(d as f64)).collect()));
        }
        GOp::Const { lit, dims } => {
            put(o, "op", Value::Str("Const".into()));
            put(o, "dtype", Value::Str(lit.dtype().hlo_name().into()));
            put(o, "dims", Value::from_usizes(dims));
            let vals: Vec<Value> = match lit {
                Literal::F32(v) => v.iter().map(|&x| Value::Num(x as f64)).collect(),
                Literal::I64(v) => v.iter().map(|&x| Value::Num(x as f64)).collect(),
                Literal::I32(v) => v.iter().map(|&x| Value::Num(x as f64)).collect(),
                Literal::Pred(v) => v.iter().map(|&x| Value::Bool(x)).collect(),
            };
            put(o, "values", Value::Arr(vals));
        }
        GOp::Unary(k) => put(
            o,
            "op",
            Value::Str(
                match k {
                    UnKind::Tanh => "Tanh",
                    UnKind::Exp => "Exp",
                    UnKind::Log => "Log",
                    UnKind::Abs => "Abs",
                    UnKind::Neg => "Neg",
                    UnKind::Sqrt => "Sqrt",
                    UnKind::Rsqrt => "Rsqrt",
                    UnKind::Relu => "Relu",
                    UnKind::Gelu => "Gelu",
                    UnKind::Sigmoid => "Sigmoid",
                    UnKind::Erf => "Erf",
                    UnKind::Floor => "Floor",
                    UnKind::Sign => "Sign",
                }
                .into(),
            ),
        ),
        GOp::Binary(k) => put(
            o,
            "op",
            Value::Str(
                match k {
                    BinKind::Add => "Add",
                    BinKind::Sub => "Sub",
                    BinKind::Mul => "Mul",
                    BinKind::Div => "Div",
                    BinKind::Max => "Maximum",
                    BinKind::Min => "Minimum",
                    BinKind::Pow => "Pow",
                }
                .into(),
            ),
        ),
        GOp::Compare(d) => put(
            o,
            "op",
            Value::Str(
                match d {
                    CmpDir::Gt => "Greater",
                    CmpDir::Lt => "Less",
                    _ => "Equal",
                }
                .into(),
            ),
        ),
        GOp::Select => put(o, "op", Value::Str("Select".into())),
        GOp::Cast { to } => {
            put(o, "op", Value::Str("Cast".into()));
            put(o, "to", Value::Str(to.hlo_name().into()));
        }
        GOp::Scale { c } => {
            put(o, "op", Value::Str("Scale".into()));
            put(o, "c", Value::Num(*c as f64));
        }
        GOp::MatMul => put(o, "op", Value::Str("MatMul".into())),
        GOp::Softmax => put(o, "op", Value::Str("Softmax".into())),
        GOp::LayerNorm { eps } => {
            put(o, "op", Value::Str("LayerNorm".into()));
            put(o, "eps", Value::Num(*eps as f64));
        }
        GOp::BiasAdd => put(o, "op", Value::Str("BiasAdd".into())),
        GOp::Split { axis, num } => {
            put(o, "op", Value::Str("Split".into()));
            put(o, "axis", Value::Num(*axis as f64));
            put(o, "num", Value::Num(*num as f64));
        }
        GOp::Concat { axis } => {
            put(o, "op", Value::Str("Concat".into()));
            put(o, "axis", Value::Num(*axis as f64));
        }
        GOp::Transpose { perm } => {
            put(o, "op", Value::Str("Transpose".into()));
            put(o, "perm", Value::from_usizes(perm));
        }
        GOp::Reshape { dims } => {
            put(o, "op", Value::Str("Reshape".into()));
            put(o, "dims", Value::Arr(dims.iter().map(|&d| Value::Num(d as f64)).collect()));
        }
        GOp::Reduce { kind, axes } => {
            put(
                o,
                "op",
                Value::Str(
                    match kind {
                        ReduceKind::Sum => "ReduceSum",
                        ReduceKind::Max => "ReduceMax",
                        ReduceKind::Min => "ReduceMax",
                        ReduceKind::Mean => "ReduceMean",
                    }
                    .into(),
                ),
            );
            put(o, "axes", Value::from_usizes(axes));
        }
        GOp::Slice { begin, size } => {
            put(o, "op", Value::Str("Slice".into()));
            put(o, "begin", Value::Arr(begin.iter().map(|&d| Value::Num(d as f64)).collect()));
            put(o, "size", Value::Arr(size.iter().map(|&d| Value::Num(d as f64)).collect()));
        }
        GOp::Pad { low, high, value } => {
            put(o, "op", Value::Str("Pad".into()));
            put(o, "low", Value::Arr(low.iter().map(|&d| Value::Num(d as f64)).collect()));
            put(o, "high", Value::Arr(high.iter().map(|&d| Value::Num(d as f64)).collect()));
            put(o, "value", Value::Num(*value as f64));
        }
        GOp::Gather { axis } => {
            put(o, "op", Value::Str("Gather".into()));
            put(o, "axis", Value::Num(*axis as f64));
        }
        GOp::Unique => put(o, "op", Value::Str("Unique".into())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TF_GRAPH: &str = r#"{
        "name": "tf_demo",
        "nodes": [
            {"name": "x", "op": "Placeholder", "dtype": "f32", "dims": [-1, 8]},
            {"name": "w", "op": "Const", "dtype": "f32", "dims": [8],
             "values": [0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 0.1]},
            {"name": "h", "op": "BiasAdd", "inputs": ["x", "w"]},
            {"name": "sp", "op": "Split", "axis": 1, "num": 2, "inputs": ["h"]},
            {"name": "y", "op": "AddV2", "inputs": ["sp:0", "sp:1"]},
            {"name": "act", "op": "Relu", "inputs": ["y"]}
        ],
        "outputs": ["act"]
    }"#;

    const PT_GRAPH: &str = r#"{
        "name": "pt_demo",
        "nodes": [
            {"name": "x", "op": "input", "dtype": "torch.float32", "dims": [-1, 8]},
            {"name": "t", "op": "torch.tanh", "inputs": ["x"]},
            {"name": "y", "op": "torch.add", "inputs": ["x", "t"]},
            {"name": "s", "op": "torch.softmax", "inputs": ["y"]}
        ],
        "outputs": ["s"]
    }"#;

    #[test]
    fn imports_tf_flavoured_graph() {
        let g = from_json(TF_GRAPH).unwrap();
        assert_eq!(g.nodes.len(), 6);
        assert!(matches!(g.nodes[3].op, GOp::Split { axis: 1, num: 2 }));
        let m = crate::bridge::lower(&g).unwrap();
        let input = crate::runtime::tensor::Tensor::f32(&[3, 8], vec![0.5; 24]);
        let r = crate::runtime::reference::eval_module(&m, &[input]).unwrap();
        assert_eq!(r.outputs[0].dims, vec![3, 4]);
    }

    #[test]
    fn imports_pytorch_flavoured_graph() {
        let g = from_json(PT_GRAPH).unwrap();
        let m = crate::bridge::lower(&g).unwrap();
        let input = crate::runtime::tensor::Tensor::f32(&[2, 8], vec![0.25; 16]);
        let r = crate::runtime::reference::eval_module(&m, &[input]).unwrap();
        assert_eq!(r.outputs[0].dims, vec![2, 8]);
    }

    #[test]
    fn json_roundtrip() {
        let g = from_json(TF_GRAPH).unwrap();
        let text = crate::util::json::to_string_pretty(&to_json(&g));
        let g2 = from_json(&text).unwrap();
        assert_eq!(g.nodes.len(), g2.nodes.len());
        for (a, b) in g.nodes.iter().zip(&g2.nodes) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.op, b.op);
            assert_eq!(a.inputs, b.inputs);
        }
        assert_eq!(g.outputs, g2.outputs);
    }

    #[test]
    fn rejects_malformed() {
        assert!(from_json("{}").is_err());
        assert!(from_json(r#"{"nodes": [], "outputs": []}"#).is_ok());
        assert!(from_json(r#"{"nodes": [{"name":"a","op":"Nope"}], "outputs": []}"#).is_err());
        assert!(from_json(
            r#"{"nodes": [{"name":"a","op":"Tanh","inputs":["missing"]}], "outputs": ["a"]}"#
        )
        .is_err());
    }
}
