//! Framework-level computation graphs — the frontend DISC bridges from.
//!
//! This is the abstraction the paper's "computation graph bridging" layer
//! consumes: a coarse-grained op graph in the vocabulary of TensorFlow /
//! PyTorch (Softmax, LayerNorm, Split, BiasAdd, …), with named nodes,
//! multi-output ops, and `-1` dynamic dims on placeholders. The bridge
//! (`crate::bridge`) lowers it to DHLO, injecting the shape constraints
//! that high-level op semantics imply but lowering would otherwise lose
//! (§4.2.1 second source).

pub mod import;

use crate::dhlo::{BinKind, CmpDir, DType, Literal, ReduceKind, UnKind};

/// Reference to one output port of a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    pub node: usize,
    pub port: usize,
}

/// Framework-level ops. Dynamic dims on placeholders are `-1`, TF-style.
#[derive(Debug, Clone, PartialEq)]
pub enum GOp {
    Placeholder { dtype: DType, dims: Vec<i64> },
    Const { lit: Literal, dims: Vec<usize> },
    Unary(UnKind),
    /// Numpy-style binary: the bridge inserts explicit broadcasts for
    /// scalar and trailing-axis (`[h]` vs `[..., h]`) operand shapes.
    Binary(BinKind),
    Compare(CmpDir),
    Select,
    Cast { to: DType },
    /// Multiply by a scalar constant (e.g. attention scaling).
    Scale { c: f32 },
    MatMul,
    /// Softmax over the last axis (composite; the bridge expands it).
    Softmax,
    /// Layer normalization over the last axis; inputs `(x, gamma, beta)`.
    LayerNorm { eps: f32 },
    /// `x + bias` with `bias: [h]` broadcast over leading axes.
    BiasAdd,
    /// Split into `num` equal parts along `axis` — the paper's running
    /// example of constraint injection. Multi-output.
    Split { axis: usize, num: usize },
    Concat { axis: usize },
    Transpose { perm: Vec<usize> },
    /// TF-style reshape; one dim may be `-1` (inferred).
    Reshape { dims: Vec<i64> },
    Reduce { kind: ReduceKind, axes: Vec<usize> },
    /// TF slice: `begin` + `size` (size `-1` = to end).
    Slice { begin: Vec<i64>, size: Vec<i64> },
    Pad { low: Vec<i64>, high: Vec<i64>, value: f32 },
    /// Embedding-style lookup along `axis`; inputs `(table, indices)`.
    Gather { axis: usize },
    Unique,
}

impl GOp {
    /// Number of output ports.
    pub fn num_outputs(&self) -> usize {
        match self {
            GOp::Split { num, .. } => *num,
            _ => 1,
        }
    }

    pub fn name(&self) -> String {
        match self {
            GOp::Placeholder { .. } => "Placeholder".into(),
            GOp::Const { .. } => "Const".into(),
            GOp::Unary(k) => format!("Unary.{}", k.name()),
            GOp::Binary(k) => format!("Binary.{}", k.name()),
            GOp::Compare(_) => "Compare".into(),
            GOp::Select => "Select".into(),
            GOp::Cast { .. } => "Cast".into(),
            GOp::Scale { .. } => "Scale".into(),
            GOp::MatMul => "MatMul".into(),
            GOp::Softmax => "Softmax".into(),
            GOp::LayerNorm { .. } => "LayerNorm".into(),
            GOp::BiasAdd => "BiasAdd".into(),
            GOp::Split { .. } => "Split".into(),
            GOp::Concat { .. } => "Concat".into(),
            GOp::Transpose { .. } => "Transpose".into(),
            GOp::Reshape { .. } => "Reshape".into(),
            GOp::Reduce { kind, .. } => format!("Reduce.{}", kind.name()),
            GOp::Slice { .. } => "Slice".into(),
            GOp::Pad { .. } => "Pad".into(),
            GOp::Gather { .. } => "Gather".into(),
            GOp::Unique => "Unique".into(),
        }
    }
}

/// One node: a named op application.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub op: GOp,
    pub inputs: Vec<Edge>,
}

/// A framework graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub outputs: Vec<Edge>,
}

impl Graph {
    pub fn node_by_name(&self, name: &str) -> Option<usize> {
        self.nodes.iter().position(|n| n.name == name)
    }
}

/// Ergonomic builder used by the workload definitions.
pub struct GraphBuilder {
    pub g: Graph,
}

impl GraphBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        GraphBuilder { g: Graph { name: name.into(), ..Default::default() } }
    }

    pub fn finish(mut self, outputs: &[Edge]) -> Graph {
        self.g.outputs = outputs.to_vec();
        self.g
    }

    pub fn add(&mut self, name: impl Into<String>, op: GOp, inputs: &[Edge]) -> Edge {
        self.g.nodes.push(Node { name: name.into(), op, inputs: inputs.to_vec() });
        Edge { node: self.g.nodes.len() - 1, port: 0 }
    }

    /// Port accessor for multi-output nodes.
    pub fn port(&self, e: Edge, port: usize) -> Edge {
        Edge { node: e.node, port }
    }

    // Conveniences used heavily by workloads.
    pub fn placeholder(&mut self, name: &str, dtype: DType, dims: &[i64]) -> Edge {
        self.add(name, GOp::Placeholder { dtype, dims: dims.to_vec() }, &[])
    }
    pub fn weight(&mut self, name: &str, dims: &[usize], seed: u64) -> Edge {
        // Deterministic pseudo-random weights (workloads embed them as
        // constants so requests carry only activations).
        let n: usize = dims.iter().product();
        let mut rng = crate::util::prng::Prng::new(seed);
        let data = rng.fill_f32(n, 0.25);
        self.add(name, GOp::Const { lit: Literal::F32(data), dims: dims.to_vec() }, &[])
    }
    pub fn unary(&mut self, name: &str, k: UnKind, x: Edge) -> Edge {
        self.add(name, GOp::Unary(k), &[x])
    }
    pub fn binary(&mut self, name: &str, k: BinKind, a: Edge, b: Edge) -> Edge {
        self.add(name, GOp::Binary(k), &[a, b])
    }
    pub fn matmul(&mut self, name: &str, a: Edge, b: Edge) -> Edge {
        self.add(name, GOp::MatMul, &[a, b])
    }
    pub fn softmax(&mut self, name: &str, x: Edge) -> Edge {
        self.add(name, GOp::Softmax, &[x])
    }
    pub fn layernorm(&mut self, name: &str, x: Edge, gamma: Edge, beta: Edge) -> Edge {
        self.add(name, GOp::LayerNorm { eps: 1e-5 }, &[x, gamma, beta])
    }
    pub fn bias_add(&mut self, name: &str, x: Edge, b: Edge) -> Edge {
        self.add(name, GOp::BiasAdd, &[x, b])
    }
    pub fn scale(&mut self, name: &str, x: Edge, c: f32) -> Edge {
        self.add(name, GOp::Scale { c }, &[x])
    }
    pub fn transpose(&mut self, name: &str, x: Edge, perm: &[usize]) -> Edge {
        self.add(name, GOp::Transpose { perm: perm.to_vec() }, &[x])
    }
    pub fn reshape(&mut self, name: &str, x: Edge, dims: &[i64]) -> Edge {
        self.add(name, GOp::Reshape { dims: dims.to_vec() }, &[x])
    }
    pub fn concat(&mut self, name: &str, xs: &[Edge], axis: usize) -> Edge {
        self.add(name, GOp::Concat { axis }, xs)
    }
    pub fn split(&mut self, name: &str, x: Edge, axis: usize, num: usize) -> Vec<Edge> {
        let e = self.add(name, GOp::Split { axis, num }, &[x]);
        (0..num).map(|p| Edge { node: e.node, port: p }).collect()
    }
    pub fn gather(&mut self, name: &str, table: Edge, idx: Edge, axis: usize) -> Edge {
        self.add(name, GOp::Gather { axis }, &[table, idx])
    }
    pub fn unique(&mut self, name: &str, x: Edge) -> Edge {
        self.add(name, GOp::Unique, &[x])
    }
    pub fn reduce(&mut self, name: &str, kind: ReduceKind, x: Edge, axes: &[usize]) -> Edge {
        self.add(name, GOp::Reduce { kind, axes: axes.to_vec() }, &[x])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_and_ports() {
        let mut b = GraphBuilder::new("g");
        let x = b.placeholder("x", DType::F32, &[-1, 8]);
        let parts = b.split("sp", x, 1, 2);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].port, 1);
        let y = b.binary("add", BinKind::Add, parts[0], parts[1]);
        let g = b.finish(&[y]);
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.nodes[1].op.num_outputs(), 2);
        assert_eq!(g.node_by_name("sp"), Some(1));
    }
}
