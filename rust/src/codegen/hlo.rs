//! HLO-text emission for fusion groups.

use crate::dhlo::{BinKind, DType, Module, Op, ReduceKind, UnKind, ValueId};
use crate::fusion::signature::external_inputs;
use crate::fusion::FusionGroup;
use crate::shape::{Dim, SymId};
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;
use std::fmt::Write as _;

/// Everything the executor needs to launch a compiled fusion kernel.
#[derive(Debug, Clone)]
pub struct KernelSpec {
    pub name: String,
    /// The HLO text module.
    pub hlo: String,
    /// External tensor inputs, in parameter order.
    pub inputs: Vec<ValueId>,
    /// Bucketed dims of each tensor parameter (executor pads inputs to
    /// these extents before launch).
    pub input_dims: Vec<Vec<usize>>,
    /// Positions (into the group's [`group_syms`] order) of the symbols
    /// whose *actual* extents are passed as trailing s32[] scalar
    /// parameters (mask extents for dynamic reduces). Positional — a cache
    /// hit may serve a *different* group with the same signature, whose
    /// SymIds differ but whose local symbol order matches.
    pub extent_locals: Vec<usize>,
    /// The root value the kernel computes.
    pub out: ValueId,
    /// Bucketed output dims (executor crops to actual afterwards).
    pub out_dims: Vec<usize>,
    pub out_dtype: DType,
}

/// Distinct canonical dynamic symbols of a group, in deterministic
/// first-appearance order over (externals, members). The bucket cache key
/// assigns extents in this order.
pub fn group_syms(m: &Module, g: &FusionGroup) -> Vec<SymId> {
    let mut out = Vec::new();
    let push_dims = |dims: &[Dim], out: &mut Vec<SymId>| {
        for &d in dims {
            if let Dim::Sym(s) = m.syms.canon_dim(d) {
                if !out.contains(&s) {
                    out.push(s);
                }
            }
        }
    };
    for e in external_inputs(m, g) {
        push_dims(&m.ty(e.value).dims.clone(), &mut out);
    }
    for &v in &g.members {
        push_dims(&m.ty(v).dims.clone(), &mut out);
    }
    out
}

struct Emitter<'m> {
    m: &'m Module,
    buckets: HashMap<SymId, usize>,
    body: Vec<String>,
    counter: usize,
    /// member value -> emitted name
    names: HashMap<ValueId, String>,
    need_regions: Vec<ReduceKind>,
    extent_syms: Vec<SymId>,
    extent_names: HashMap<SymId, String>,
}

impl<'m> Emitter<'m> {
    fn bucket_dims(&self, dims: &[Dim]) -> Result<Vec<usize>> {
        dims.iter()
            .map(|&d| match self.m.syms.canon_dim(d) {
                Dim::Fixed(n) => Ok(n),
                Dim::Sym(s) => self
                    .buckets
                    .get(&s)
                    .copied()
                    .ok_or_else(|| anyhow::anyhow!("no bucket for symbol {s}")),
            })
            .collect()
    }

    fn fresh(&mut self, prefix: &str) -> String {
        self.counter += 1;
        format!("{prefix}{}", self.counter)
    }

    fn line(&mut self, name: &str, ty: &str, rhs: &str) {
        self.body.push(format!("  {name} = {ty} {rhs}"));
    }

    /// Emit an instruction and return its name.
    fn emit_simple(&mut self, prefix: &str, ty: &str, rhs: String) -> String {
        let n = self.fresh(prefix);
        self.line(&n, ty, &rhs);
        n
    }

    fn scalar_const_f32(&mut self, v: f32) -> String {
        let rhs = format!("constant({})", crate::dhlo::types::format_f32_hlo(v));
        self.emit_simple("c", "f32[]", rhs)
    }

    /// Broadcast a scalar-typed value to `dims`.
    fn splat(&mut self, scalar: &str, dtype: DType, dims: &[usize]) -> String {
        let ty = type_str(dtype, dims);
        self.emit_simple("b", &ty, format!("broadcast({scalar}), dimensions={{}}"))
    }

    fn splat_f32(&mut self, v: f32, dims: &[usize]) -> String {
        let c = self.scalar_const_f32(v);
        self.splat(&c, DType::F32, dims)
    }

    fn extent_param_name(&mut self, s: SymId) -> String {
        if let Some(n) = self.extent_names.get(&s) {
            return n.clone();
        }
        let n = format!("ext{}", self.extent_syms.len());
        self.extent_syms.push(s);
        self.extent_names.insert(s, n.clone());
        n
    }
}

/// HLO type string with default (row-major) layout.
pub fn type_str(dtype: DType, dims: &[usize]) -> String {
    let d: Vec<String> = dims.iter().map(|x| x.to_string()).collect();
    if dims.is_empty() {
        format!("{}[]", dtype.hlo_name())
    } else {
        let layout: Vec<String> = (0..dims.len()).rev().map(|i| i.to_string()).collect();
        format!("{}[{}]{{{}}}", dtype.hlo_name(), d.join(","), layout.join(","))
    }
}

fn un_hlo_name(k: UnKind) -> Option<&'static str> {
    Some(match k {
        UnKind::Abs => "abs",
        UnKind::Neg => "negate",
        UnKind::Exp => "exponential",
        UnKind::Log => "log",
        UnKind::Tanh => "tanh",
        UnKind::Sqrt => "sqrt",
        UnKind::Rsqrt => "rsqrt",
        UnKind::Floor => "floor",
        UnKind::Sign => "sign",
        UnKind::Relu | UnKind::Gelu | UnKind::Erf | UnKind::Sigmoid => return None,
    })
}

fn bin_hlo_name(k: BinKind) -> &'static str {
    match k {
        BinKind::Add => "add",
        BinKind::Sub => "subtract",
        BinKind::Mul => "multiply",
        BinKind::Div => "divide",
        BinKind::Max => "maximum",
        BinKind::Min => "minimum",
        BinKind::Pow => "power",
    }
}

/// Emit the Abramowitz–Stegun erf expansion (identical to the reference
/// interpreter's formula, so compiled and interpreted numerics agree).
fn emit_erf(e: &mut Emitter, x: &str, dims: &[usize]) -> String {
    let ty = type_str(DType::F32, dims);
    let sign = e.emit_simple("v", &ty, format!("sign({x})"));
    let ax = e.emit_simple("v", &ty, format!("abs({x})"));
    let c = e.splat_f32(0.3275911, dims);
    let cx = e.emit_simple("v", &ty, format!("multiply({c}, {ax})"));
    let one = e.splat_f32(1.0, dims);
    let denom = e.emit_simple("v", &ty, format!("add({one}, {cx})"));
    let t = e.emit_simple("v", &ty, format!("divide({one}, {denom})"));
    // Horner: ((((a5 t + a4) t + a3) t + a2) t + a1) t
    let coefs = [1.061405429f32, -1.453152027, 1.421413741, -0.284496736, 0.254829592];
    let mut acc = e.splat_f32(coefs[0], dims);
    for &cf in &coefs[1..] {
        let prod = e.emit_simple("v", &ty, format!("multiply({acc}, {t})"));
        let cc = e.splat_f32(cf, dims);
        acc = e.emit_simple("v", &ty, format!("add({prod}, {cc})"));
    }
    let poly_t = e.emit_simple("v", &ty, format!("multiply({acc}, {t})"));
    let xx = e.emit_simple("v", &ty, format!("multiply({ax}, {ax})"));
    let nxx = e.emit_simple("v", &ty, format!("negate({xx})"));
    let exx = e.emit_simple("v", &ty, format!("exponential({nxx})"));
    let prod = e.emit_simple("v", &ty, format!("multiply({poly_t}, {exx})"));
    let y = e.emit_simple("v", &ty, format!("subtract({one}, {prod})"));
    e.emit_simple("v", &ty, format!("multiply({sign}, {y})"))
}

fn emit_unary(e: &mut Emitter, k: UnKind, x: &str, dims: &[usize]) -> String {
    let ty = type_str(DType::F32, dims);
    match k {
        UnKind::Relu => {
            let z = e.splat_f32(0.0, dims);
            e.emit_simple("v", &ty, format!("maximum({x}, {z})"))
        }
        UnKind::Sigmoid => {
            // 1 / (1 + exp(-x)) — matches the reference formula.
            let nx = e.emit_simple("v", &ty, format!("negate({x})"));
            let ex = e.emit_simple("v", &ty, format!("exponential({nx})"));
            let one = e.splat_f32(1.0, dims);
            let den = e.emit_simple("v", &ty, format!("add({one}, {ex})"));
            e.emit_simple("v", &ty, format!("divide({one}, {den})"))
        }
        UnKind::Erf => emit_erf(e, x, dims),
        UnKind::Gelu => {
            // 0.5 * x * (1 + erf(x / sqrt(2)))
            let s = e.splat_f32(std::f32::consts::SQRT_2, dims);
            let xs = e.emit_simple("v", &ty, format!("divide({x}, {s})"));
            let erf = emit_erf(e, &xs, dims);
            let one = e.splat_f32(1.0, dims);
            let t1 = e.emit_simple("v", &ty, format!("add({one}, {erf})"));
            let xt = e.emit_simple("v", &ty, format!("multiply({x}, {t1})"));
            let half = e.splat_f32(0.5, dims);
            e.emit_simple("v", &ty, format!("multiply({half}, {xt})"))
        }
        _ => {
            let name = un_hlo_name(k).expect("covered above");
            e.emit_simple("v", &ty, format!("{name}({x})"))
        }
    }
}

/// Mask the padded tail of `operand` (shape `dims`) along the dynamic
/// reduced axes: out-of-range lanes are replaced by `neutral`.
fn emit_mask(
    e: &mut Emitter,
    m: &Module,
    operand: &str,
    operand_dims_sym: &[Dim],
    dims: &[usize],
    axes: &[usize],
    neutral: f32,
) -> Result<String> {
    let ty_pred = type_str(DType::Pred, dims);
    let ty_s32 = type_str(DType::I32, dims);
    let mut mask: Option<String> = None;
    for &a in axes {
        let canon = m.syms.canon_dim(operand_dims_sym[a]);
        if let Dim::Sym(s) = canon {
            let ext = e.extent_param_name(s);
            let iota = e.emit_simple("v", &ty_s32, format!("iota(), iota_dimension={a}"));
            let extb = e.splat(&ext, DType::I32, dims);
            let cmp =
                e.emit_simple("v", &ty_pred, format!("compare({iota}, {extb}), direction=LT"));
            mask = Some(match mask {
                None => cmp,
                Some(prev) => e.emit_simple("v", &ty_pred, format!("and({prev}, {cmp})")),
            });
        }
    }
    match mask {
        None => Ok(operand.to_string()),
        Some(mk) => {
            let neutral_b = e.splat_f32(neutral, dims);
            let ty = type_str(DType::F32, dims);
            Ok(e.emit_simple("v", &ty, format!("select({mk}, {operand}, {neutral_b})")))
        }
    }
}

fn region_text(kind: ReduceKind) -> (&'static str, &'static str) {
    match kind {
        ReduceKind::Sum | ReduceKind::Mean => ("region_add", "add"),
        ReduceKind::Max => ("region_max", "maximum"),
        ReduceKind::Min => ("region_min", "minimum"),
    }
}

/// Emit a fusion group as an HLO-text kernel at the given bucket extents.
///
/// `buckets` maps each canonical dynamic symbol of the group (see
/// [`group_syms`]) to its bucketed extent.
pub fn emit_group(
    m: &Module,
    g: &FusionGroup,
    buckets: &HashMap<SymId, usize>,
    name: &str,
) -> Result<KernelSpec> {
    let externals = external_inputs(m, g);
    let mut e = Emitter {
        m,
        buckets: buckets.clone(),
        body: Vec::new(),
        counter: 0,
        names: HashMap::new(),
        need_regions: Vec::new(),
        extent_syms: Vec::new(),
        extent_names: HashMap::new(),
    };

    // Tensor parameters.
    let mut param_types = Vec::new();
    let mut input_dims = Vec::new();
    for (i, ext) in externals.iter().enumerate() {
        let t = m.ty(ext.value);
        ensure!(t.dtype != DType::Pred, "pred kernel inputs unsupported");
        let dims = e.bucket_dims(&t.dims)?;
        let ty = type_str(t.dtype, &dims);
        let pname = format!("p{i}");
        e.line(&pname, &ty, &format!("parameter({i})"));
        e.names.insert(ext.value, pname);
        param_types.push(ty);
        input_dims.push(dims);
    }

    // Body: members in topological order. Extent parameters are discovered
    // during emission and appended after the tensor parameters, so we emit
    // the body into a scratch buffer first.
    let header_len = e.body.len();
    for &v in &g.members {
        let ins = &m.instrs[v];
        let dims = e.bucket_dims(&ins.ty.dims)?;
        let ty = type_str(ins.ty.dtype, &dims);
        let opnames: Vec<String> = ins
            .operands
            .iter()
            .map(|o| {
                e.names
                    .get(o)
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("operand %{o} not materialized in kernel"))
            })
            .collect::<Result<_>>()?;
        let out_name = match &ins.op {
            Op::Un(k) => {
                ensure!(ins.ty.dtype == DType::F32, "fused unary must be f32");
                emit_unary(&mut e, *k, &opnames[0], &dims)
            }
            Op::Bin(k) => e.emit_simple(
                "v",
                &ty,
                format!("{}({}, {})", bin_hlo_name(*k), opnames[0], opnames[1]),
            ),
            Op::Cmp(d) => e.emit_simple(
                "v",
                &ty,
                format!("compare({}, {}), direction={}", opnames[0], opnames[1], d.hlo_direction()),
            ),
            Op::Select => e.emit_simple(
                "v",
                &ty,
                format!("select({}, {}, {})", opnames[0], opnames[1], opnames[2]),
            ),
            Op::Convert(_) => e.emit_simple("v", &ty, format!("convert({})", opnames[0])),
            Op::Broadcast { dims: mapping } => {
                let map: Vec<String> = mapping.iter().map(|d| d.to_string()).collect();
                e.emit_simple(
                    "v",
                    &ty,
                    format!("broadcast({}), dimensions={{{}}}", opnames[0], map.join(",")),
                )
            }
            Op::Transpose { perm } => {
                let p: Vec<String> = perm.iter().map(|d| d.to_string()).collect();
                e.emit_simple(
                    "v",
                    &ty,
                    format!("transpose({}), dimensions={{{}}}", opnames[0], p.join(",")),
                )
            }
            Op::Reduce { kind, axes } => {
                ensure!(ins.ty.dtype == DType::F32, "fused reduce must be f32");
                let operand_ty = m.ty(ins.operands[0]).clone();
                let operand_bdims = e.bucket_dims(&operand_ty.dims)?;
                let masked = emit_mask(
                    &mut e,
                    m,
                    &opnames[0],
                    &operand_ty.dims,
                    &operand_bdims,
                    axes,
                    kind.neutral(),
                )?;
                let (region, _) = region_text(*kind);
                if !e.need_regions.contains(kind) {
                    e.need_regions.push(*kind);
                }
                let init = e.scalar_const_f32(kind.neutral());
                let ax: Vec<String> = axes.iter().map(|a| a.to_string()).collect();
                let red = e.emit_simple(
                    "v",
                    &ty,
                    format!(
                        "reduce({masked}, {init}), dimensions={{{}}}, to_apply={region}",
                        ax.join(",")
                    ),
                );
                if *kind == ReduceKind::Mean {
                    // Divide by the *actual* reduced element count.
                    let mut divisor: Option<String> = None;
                    for &a in axes {
                        let term = match m.syms.canon_dim(operand_ty.dims[a]) {
                            Dim::Fixed(n) => e.scalar_const_f32(n as f32),
                            Dim::Sym(s) => {
                                let ext = e.extent_param_name(s);
                                e.emit_simple("v", "f32[]", format!("convert({ext})"))
                            }
                        };
                        divisor = Some(match divisor {
                            None => term,
                            Some(prev) => {
                                e.emit_simple("v", "f32[]", format!("multiply({prev}, {term})"))
                            }
                        });
                    }
                    let div = divisor.expect("mean reduce has axes");
                    let divb = e.splat(&div, DType::F32, &dims);
                    e.emit_simple("v", &ty, format!("divide({red}, {divb})"))
                } else {
                    red
                }
            }
            other => bail!("op {} cannot be emitted in a fused kernel", other.name()),
        };
        e.names.insert(v, out_name);
    }

    // Extent (s32 scalar) parameters come after the tensor parameters.
    let n_tensor = externals.len();
    let mut param_lines = Vec::new();
    for (j, s) in e.extent_syms.iter().enumerate() {
        let pname = e.extent_names[s].clone();
        param_lines.push(format!("  {pname} = s32[] parameter({})", n_tensor + j));
        param_types.push("s32[]".to_string());
    }
    // Insert extent parameter lines right after the tensor parameters.
    let mut body = e.body.clone();
    let tail = body.split_off(header_len);
    body.extend(param_lines);
    body.extend(tail);

    // ROOT.
    let root_name = e.names[&g.root].clone();
    let out_dims = e.bucket_dims(&m.ty(g.root).dims)?;
    let out_dtype = m.ty(g.root).dtype;
    ensure!(out_dtype != DType::Pred, "pred kernel outputs unsupported");
    let root_ty = type_str(out_dtype, &out_dims);
    // Re-emit the root under a ROOT alias via a copy to keep naming simple.
    body.push(format!("  ROOT out = {root_ty} copy({root_name})"));

    // Assemble module text.
    let mut hlo = String::new();
    let _ = write!(
        hlo,
        "HloModule {name}, entry_computation_layout={{({})->{root_ty}}}\n\n",
        param_types.join(", ")
    );
    for kind in &e.need_regions {
        let (rname, rop) = region_text(*kind);
        let _ = write!(
            hlo,
            "{rname} {{\n  {rname}_a = f32[] parameter(0)\n  {rname}_b = f32[] parameter(1)\n  ROOT {rname}_r = f32[] {rop}({rname}_a, {rname}_b)\n}}\n\n"
        );
    }
    hlo.push_str("ENTRY main {\n");
    for l in &body {
        hlo.push_str(l);
        hlo.push('\n');
    }
    hlo.push_str("}\n");

    let locals = group_syms(m, g);
    let extent_locals = e
        .extent_syms
        .iter()
        .map(|s| {
            locals
                .iter()
                .position(|l| l == s)
                .expect("extent symbol always appears in the group's symbol list")
        })
        .collect();
    Ok(KernelSpec {
        name: name.to_string(),
        hlo,
        inputs: externals.iter().map(|x| x.value).collect(),
        input_dims,
        extent_locals,
        out: g.root,
        out_dims,
        out_dtype,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::Builder;
    use crate::fusion::{plan, FusionOptions};
    use crate::runtime::pjrt::Device;
    use crate::runtime::tensor::Tensor;

    fn bucket_all(m: &Module, g: &FusionGroup, n: usize) -> HashMap<SymId, usize> {
        group_syms(m, g).into_iter().map(|s| (s, n)).collect()
    }

    #[test]
    fn emit_elementwise_chain_runs() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let t = b.unary(UnKind::Tanh, x);
        let y = b.add(x, t).unwrap();
        let m = b.finish(vec![y]);
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let spec = emit_group(&m, g, &bucket_all(&m, g, 8), "k0").unwrap();
        assert!(spec.extent_locals.is_empty(), "no reduce, no masks: {}", spec.hlo);

        let dev = Device::cpu().unwrap();
        let exe = dev.compile_hlo_text(&spec.hlo).unwrap();
        // Actual length 5, bucket 8 — pad with zeros.
        let mut data = vec![0.5f32, -1.0, 0.0, 2.0, -0.25];
        let actual = data.clone();
        data.resize(8, 0.0);
        let out = exe
            .run(&[&Tensor::f32(&[8], data)], &spec.out_dims, spec.out_dtype)
            .unwrap();
        let v = out.as_f32().unwrap();
        for (i, &a) in actual.iter().enumerate() {
            assert!((v[i] - (a + a.tanh())).abs() < 1e-6, "lane {i}");
        }
    }

    #[test]
    fn emit_masked_softmax_runs() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let s2 = b.dyn_dim("m", 0, 1);
        let x = b.param(DType::F32, vec![s, s2]);
        let y = b.softmax_last(x).unwrap();
        let m = b.finish(vec![y]);
        let p = plan(&m, &FusionOptions::default());

        // Execute the groups in dependency order against a bucketed input
        // and compare with the reference on the valid box.
        let dev = Device::cpu().unwrap();
        let actual_rows = 2usize;
        let actual_cols = 3usize;
        let (rb, cb) = (2usize, 4usize); // bucket cols up
        let input = Tensor::f32(&[2, 3], vec![1.0, 2.0, 3.0, 0.5, 0.5, 0.5]);
        // Reference.
        let r = crate::runtime::reference::eval_module(&m, &[input.clone()]).unwrap();
        let want = r.outputs[0].as_f32().unwrap().to_vec();

        // Padded input (garbage in the pad to prove masking).
        let mut padded = vec![777.0f32; rb * cb];
        for i in 0..actual_rows {
            for j in 0..actual_cols {
                padded[i * cb + j] = input.as_f32().unwrap()[i * actual_cols + j];
            }
        }

        // Run groups topologically; intermediate values keyed by root id.
        let mut vals: HashMap<ValueId, Tensor> = HashMap::new();
        vals.insert(x, Tensor::f32(&[rb, cb], padded));
        let mut groups: Vec<&FusionGroup> = p.groups.iter().collect();
        groups.sort_by_key(|g| g.root);
        for g in groups {
            let syms = group_syms(&m, g);
            let mut buckets = HashMap::new();
            let mut extents = HashMap::new();
            for s in &syms {
                // Identify which sym is rows vs cols by its bound value.
                // rows sym resolves to 2 (bucket 2), cols to 3 (bucket 4).
                let is_rows = m.syms.canon_dim(m.ty(x).dims[0]) == crate::shape::Dim::Sym(*s);
                buckets.insert(*s, if is_rows { rb } else { cb });
                extents.insert(*s, if is_rows { actual_rows } else { actual_cols });
            }
            let spec = emit_group(&m, g, &buckets, "k").unwrap();
            let exe = dev.compile_hlo_text(&spec.hlo).unwrap();
            let mut args: Vec<Tensor> =
                spec.inputs.iter().map(|v| vals[v].clone()).collect();
            for &li in &spec.extent_locals {
                args.push(Tensor::i32(&[], vec![extents[&syms[li]] as i32]));
            }
            let arg_refs: Vec<&Tensor> = args.iter().collect();
            let out = exe.run(&arg_refs, &spec.out_dims, spec.out_dtype).unwrap();
            vals.insert(g.root, out);
        }
        let got = vals[&m.outputs[0]].as_f32().unwrap();
        for i in 0..actual_rows {
            for j in 0..actual_cols {
                let w = want[i * actual_cols + j];
                let g = got[i * cb + j];
                assert!((w - g).abs() < 1e-5, "({i},{j}): want {w}, got {g}");
            }
        }
    }

    #[test]
    fn emit_gelu_matches_reference() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let y = b.unary(UnKind::Gelu, x);
        let m = b.finish(vec![y]);
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let spec = emit_group(&m, g, &bucket_all(&m, g, 4), "gelu").unwrap();
        let dev = Device::cpu().unwrap();
        let exe = dev.compile_hlo_text(&spec.hlo).unwrap();
        let input = vec![-2.0f32, -0.5, 0.5, 2.0];
        let out = exe
            .run(&[&Tensor::f32(&[4], input.clone())], &spec.out_dims, spec.out_dtype)
            .unwrap();
        let r = crate::runtime::reference::eval_module(&m, &[Tensor::f32(&[4], input)]).unwrap();
        let diff = out.max_abs_diff(&r.outputs[0]).unwrap();
        assert!(diff < 1e-6, "compiled vs reference gelu diff {diff}");
    }

    #[test]
    fn mean_reduce_divides_by_actual() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let s2 = b.dyn_dim("m", 0, 1);
        let x = b.param(DType::F32, vec![s, s2]);
        let y = b.reduce(ReduceKind::Mean, x, vec![1]).unwrap();
        let m = b.finish(vec![y]);
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let syms = group_syms(&m, g);
        let buckets: HashMap<SymId, usize> =
            syms.iter().map(|&s| (s, 4usize)).collect();
        let spec = emit_group(&m, g, &buckets, "mean").unwrap();
        assert_eq!(spec.extent_locals.len(), 1, "only the reduced dim needs an extent");
        let dev = Device::cpu().unwrap();
        let exe = dev.compile_hlo_text(&spec.hlo).unwrap();
        // actual 2x3 in a 4x4 bucket, garbage elsewhere.
        let mut padded = vec![500.0f32; 16];
        let data = [3.0f32, 6.0, 9.0, 1.0, 2.0, 3.0];
        for i in 0..2 {
            for j in 0..3 {
                padded[i * 4 + j] = data[i * 3 + j];
            }
        }
        let out = exe
            .run(
                &[&Tensor::f32(&[4, 4], padded), &Tensor::i32(&[], vec![3])],
                &spec.out_dims,
                spec.out_dtype,
            )
            .unwrap();
        let v = out.as_f32().unwrap();
        assert!((v[0] - 6.0).abs() < 1e-6);
        assert!((v[1] - 2.0).abs() < 1e-6);
    }
}
