//! Device-side code generation (§4.3 "fusion and code generation").
//!
//! For each fusion group the emitter produces an HLO-text kernel at
//! *bucketed* extents: every dynamic dimension is rounded up by the active
//! [`BucketPolicy`], so one compiled executable serves every runtime shape
//! that lands in the same bucket — DISC's "compile once per pattern"
//! property, adapted to an AOT-executable device (see DESIGN.md
//! §Hardware-Adaptation: this is the same mechanism as the paper's
//! shape-adaptive fusion configuration, where a family of kernel variants
//! plus host-side selection logic replaces per-shape recompilation).
//!
//! Reductions over dynamic axes are masked in-kernel against s32 runtime
//! extent parameters (iota → compare → select with the reduce's neutral
//! element), so tail garbage in the padding never contaminates results.
//!
//! The static [`BucketPolicy`] enum below is the compile-time *base*
//! policy. Under live traffic the serving path can layer a derived,
//! epoch-stamped [`policy::Boundaries`] on top of it (cut points fitted to
//! the observed extent histogram, swapped in without a compile stall) —
//! see [`policy`] for the traffic-adaptive machinery.

pub mod cache;
pub mod hlo;
pub mod policy;
pub mod store;

pub use cache::{CacheStats, KernelCache};
pub use hlo::{emit_group, KernelSpec};
pub use policy::{
    derive_boundaries, Boundaries, ExtentHistogram, HistSnapshot, PolicyEpoch, PolicySwitch,
};
pub use store::{Fetch, KernelStore, StoreSnapshot};

/// How dynamic extents map to compiled-kernel extents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BucketPolicy {
    /// Exact extents: one executable per concrete shape — the XLA-like
    /// static pipeline (fast kernels, unbounded recompilation).
    Exact,
    /// Round up to the next power of two (default dynamic policy).
    NextPow2,
    /// Round up to a multiple of `m` (TPU-lane-friendly alternative,
    /// benchmarked in the ablations).
    MultipleOf(usize),
}

impl BucketPolicy {
    pub fn bucket(&self, n: usize) -> usize {
        match self {
            BucketPolicy::Exact => n.max(1),
            BucketPolicy::NextPow2 => crate::util::next_pow2(n),
            BucketPolicy::MultipleOf(m) => crate::util::round_up(n.max(1), *m),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_policies() {
        assert_eq!(BucketPolicy::Exact.bucket(17), 17);
        assert_eq!(BucketPolicy::NextPow2.bucket(17), 32);
        assert_eq!(BucketPolicy::NextPow2.bucket(16), 16);
        assert_eq!(BucketPolicy::MultipleOf(128).bucket(17), 128);
        assert_eq!(BucketPolicy::MultipleOf(128).bucket(130), 256);
        assert_eq!(BucketPolicy::Exact.bucket(0), 1);
    }
}
