//! The process-wide, shard-locked compiled-kernel store plus the
//! asynchronous compile service behind it.
//!
//! DISC's §2 pathology is compilation overhead leaking into serving
//! latency. PR 1–2 removed *recurring* compilation from the hot path (one
//! compile per pattern×bucket); this module removes the remaining two
//! leaks a multi-worker serving process would still pay:
//!
//! 1. **Duplicate compiles across workers.** M executor workers used to
//!    own M private kernel caches, so each worker compiled every
//!    pattern×bucket it touched. The [`KernelStore`] is shared by every
//!    [`crate::codegen::KernelCache`] handle (and by the GEMM library's
//!    entry/prepare-kernel caches) in the process: each (signature,
//!    bucketed-extents) key compiles **exactly once**, whichever worker
//!    gets there first. Lookups are sharded (`SHARDS` independent mutexes
//!    keyed by key hash) so concurrent hot-path hits do not serialize on
//!    one lock.
//! 2. **Inline compilation on the request thread.** A miss *enqueues* the
//!    compile on the background [`CompilePool`] instead of running it on
//!    the serving thread. First-touch requests still block — correctness
//!    requires the kernel — but the wait is observable
//!    (`StoreStats::stall`, surfaced as `RunMetrics::compile_stall`), and
//!    *speculative* warms ([`KernelStore::prefetch`], driven by the
//!    executor's neighbor-bucket heuristic) overlap compilation with
//!    serving entirely: by the time traffic reaches the next bucket, the
//!    kernel is resident and the stall is zero.
//!
//! Single-flight: a concurrent miss on a key that is already compiling
//! waits on the first caller's in-flight slot rather than compiling again
//! (`StoreStats::dedup_hits` counts these joins).

use crate::runtime::faults::FaultSite;
use crate::runtime::pjrt::{Device, Executable};
// The store is process-shared, so every lock goes through the
// poison-recovering `relock`: a panicking worker (or an injected chaos
// panic) must not cascade into every other worker's kernel lookups. The
// protected state is a plain map of slots — always consistent at mutation
// granularity.
use crate::util::relock;
use anyhow::{anyhow, Result};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Store key: a shape-agnostic kernel identity (pattern signature,
/// namespaced by producer — `fused:`, `lib:gemm`, `lib:prep`) plus the
/// bucketed extents the kernel was specialized to.
pub type StoreKey = (String, Vec<usize>);

/// Number of independently locked shards. Small and fixed: the store holds
/// at most a few hundred entries; the point is that M workers hitting
/// *different* keys never contend.
const SHARDS: usize = 8;

/// Background compile threads. Two is enough to overlap a speculative warm
/// with a first-touch compile without oversubscribing the test machines.
const COMPILE_THREADS: usize = 2;

/// One in-flight compilation; waiters block on the condvar until `state`
/// leaves `Pending`. Errors are broadcast to every waiter as strings (the
/// pool thread cannot hand the same `anyhow::Error` to N callers).
struct Flight {
    state: Mutex<FlightState>,
    cv: Condvar,
}

enum FlightState {
    Pending,
    Done(std::result::Result<Arc<Executable>, String>),
}

impl Flight {
    fn new() -> Flight {
        Flight { state: Mutex::new(FlightState::Pending), cv: Condvar::new() }
    }

    fn finish(&self, r: std::result::Result<Arc<Executable>, String>) {
        *relock(&self.state) = FlightState::Done(r);
        self.cv.notify_all();
    }

    fn wait(&self) -> Result<Arc<Executable>> {
        let mut st = relock(&self.state);
        while matches!(*st, FlightState::Pending) {
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        match &*st {
            FlightState::Done(Ok(e)) => Ok(e.clone()),
            FlightState::Done(Err(msg)) => Err(anyhow!("kernel compile failed: {msg}")),
            FlightState::Pending => unreachable!(),
        }
    }
}

enum Slot {
    Ready(Arc<Executable>),
    InFlight(Arc<Flight>),
}

type Shard = Mutex<HashMap<StoreKey, Slot>>;

/// Store-level counters (process totals, atomics — the per-worker view
/// lives in `CacheStats` / `LibraryStats`).
#[derive(Default)]
pub struct StoreStats {
    /// Lookup found a ready executable.
    hits: AtomicU64,
    /// Lookup initiated a compile (the only counter that costs a compile
    /// on the demand path — "misses flat across workers" is the
    /// compile-once claim).
    misses: AtomicU64,
    /// Lookup joined another caller's in-flight compile (single-flight).
    dedup_hits: AtomicU64,
    /// Background warms enqueued by `prefetch` (not counted as misses:
    /// they are off the request path by construction).
    prefetches: AtomicU64,
    /// Nanoseconds callers spent blocked waiting on the compile service.
    stall_ns: AtomicU64,
    /// Nanoseconds of actual device compilation performed by the pool.
    compile_ns: AtomicU64,
}

/// Plain snapshot of [`StoreStats`] for reporting.
#[derive(Debug, Clone, Default)]
pub struct StoreSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub dedup_hits: u64,
    pub prefetches: u64,
    pub stall: Duration,
    pub compile_time: Duration,
    pub entries: usize,
}

/// How one `get_or_compile` call was served — the caller folds this into
/// its per-handle stats (`CacheStats`, `LibraryStats`) and `RunMetrics`.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fetch {
    /// This call initiated the compile (first touch of the key).
    pub compiled: bool,
    /// This call joined an in-flight compile started by another caller.
    pub deduped: bool,
    /// Wall time this call spent blocked on the compile service (zero on
    /// a ready hit — the steady-state guarantee).
    pub stall: Duration,
}

struct Job {
    key: StoreKey,
    name: String,
    hlo: String,
    flight: Arc<Flight>,
}

/// Drop guard armed around one compile job. If anything between "job
/// dequeued" and "flight resolved" panics, the guard removes the in-flight
/// slot and fails the flight — so every waiter gets an error and a later
/// lookup retries. Without it, a mid-compile panic would wedge
/// `FlightState::Pending` forever and deadlock all joiners.
struct FlightGuard {
    shards: Arc<Vec<Shard>>,
    key: StoreKey,
    flight: Arc<Flight>,
    armed: bool,
}

impl FlightGuard {
    fn new(shards: &Arc<Vec<Shard>>, job: &Job) -> FlightGuard {
        FlightGuard {
            shards: shards.clone(),
            key: job.key.clone(),
            flight: job.flight.clone(),
            armed: true,
        }
    }

    fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for FlightGuard {
    fn drop(&mut self) {
        if self.armed {
            relock(&self.shards[shard_of(&self.key)]).remove(&self.key);
            self.flight.finish(Err("compile worker panicked mid-compile".into()));
        }
    }
}

/// The background compile service: a bounded set of threads draining one
/// job queue, compiling HLO on the shared device and publishing results
/// into the store's shards.
struct CompilePool {
    tx: Option<Sender<Job>>,
    threads: Vec<JoinHandle<()>>,
}

impl CompilePool {
    fn spawn(device: Arc<Device>, shards: Arc<Vec<Shard>>, stats: Arc<StoreStats>) -> CompilePool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let threads = (0..COMPILE_THREADS)
            .map(|i| {
                let rx = rx.clone();
                let device = device.clone();
                let shards = shards.clone();
                let stats = stats.clone();
                std::thread::Builder::new()
                    .name(format!("disc-compile-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = relock(&rx);
                            guard.recv()
                        };
                        let Ok(job) = job else { return };
                        // The guard keeps a panicking compile from wedging
                        // the flight; catch_unwind keeps the pool thread
                        // alive to serve the next job.
                        let panic_guard = FlightGuard::new(&shards, &job);
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            if let Some(f) = device.faults() {
                                if f.should_fail(FaultSite::CompilePanic) {
                                    panic!("injected compile-panic fault");
                                }
                            }
                            device.compile_hlo_text_named(&job.name, &job.hlo)
                        }));
                        let shard = &shards[shard_of(&job.key)];
                        match result {
                            Ok(Ok(exe)) => {
                                stats.compile_ns.fetch_add(
                                    exe.compile_time.as_nanos() as u64,
                                    Ordering::Relaxed,
                                );
                                let exe = Arc::new(exe);
                                relock(shard).insert(job.key.clone(), Slot::Ready(exe.clone()));
                                job.flight.finish(Ok(exe));
                                panic_guard.disarm();
                            }
                            Ok(Err(e)) => {
                                // Drop the in-flight slot so a later lookup
                                // may retry; every current waiter sees the
                                // error.
                                relock(shard).remove(&job.key);
                                job.flight.finish(Err(format!("{e:#}")));
                                panic_guard.disarm();
                            }
                            // Panicked: FlightGuard::drop fails the flight
                            // and clears the slot.
                            Err(_) => drop(panic_guard),
                        }
                    })
                    .expect("spawning compile thread")
            })
            .collect();
        CompilePool { tx: Some(tx), threads }
    }
}

impl Drop for CompilePool {
    fn drop(&mut self) {
        // Closing the channel stops the workers after the queue drains.
        self.tx.take();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn shard_of(key: &StoreKey) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) % SHARDS
}

/// The shared kernel store. One per process in a serving deployment
/// (`DiscCompiler` owns it and threads it through every model/worker it
/// builds); tests may build private ones.
pub struct KernelStore {
    device: Arc<Device>,
    shards: Arc<Vec<Shard>>,
    stats: Arc<StoreStats>,
    /// Lazily spawned: plenty of tests touch a store once or never, and
    /// should not pay two thread spawns for it.
    pool: Mutex<Option<CompilePool>>,
}

impl KernelStore {
    pub fn new(device: Arc<Device>) -> KernelStore {
        let shards = Arc::new((0..SHARDS).map(|_| Mutex::new(HashMap::new())).collect::<Vec<_>>());
        KernelStore {
            device,
            shards,
            stats: Arc::new(StoreStats::default()),
            pool: Mutex::new(None),
        }
    }

    pub fn device(&self) -> &Arc<Device> {
        &self.device
    }

    /// Enqueue a job on the compile pool, spawning it on first use.
    fn submit(&self, job: Job) {
        let mut pool = relock(&self.pool);
        let pool = pool.get_or_insert_with(|| {
            CompilePool::spawn(self.device.clone(), self.shards.clone(), self.stats.clone())
        });
        // Send can only fail if the workers died; surface that to waiters
        // rather than deadlocking them.
        if let Some(tx) = &pool.tx {
            if let Err(std::sync::mpsc::SendError(job)) = tx.send(job) {
                self.fail_inflight(&job.key, &job.flight, "compile pool is down".into());
            }
        }
    }

    /// Resolve an in-flight slot with an error and remove it so later
    /// lookups can retry.
    fn fail_inflight(&self, key: &StoreKey, flight: &Arc<Flight>, msg: String) {
        relock(&self.shards[shard_of(key)]).remove(key);
        flight.finish(Err(msg));
    }

    /// Look up the executable for `(sig, extents)`, compiling it through
    /// the background pool on a miss. `emit` produces `(kernel_name,
    /// hlo_text)` and runs only when this call actually owns the compile.
    ///
    /// Single-flight: concurrent misses on the same key block on one
    /// compile. The returned [`Fetch`] says how the call was served.
    pub fn get_or_compile<F>(
        &self,
        sig: &str,
        extents: &[usize],
        emit: F,
    ) -> Result<(Arc<Executable>, Fetch)>
    where
        F: FnOnce() -> Result<(String, String)>,
    {
        let key: StoreKey = (sig.to_string(), extents.to_vec());
        let flight;
        let joined;
        {
            let mut map = relock(&self.shards[shard_of(&key)]);
            match map.get(&key) {
                Some(Slot::Ready(e)) => {
                    self.stats.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((e.clone(), Fetch::default()));
                }
                Some(Slot::InFlight(f)) => {
                    flight = f.clone();
                    joined = true;
                }
                None => {
                    let f = Arc::new(Flight::new());
                    map.insert(key.clone(), Slot::InFlight(f.clone()));
                    flight = f;
                    joined = false;
                }
            }
        }

        let t0 = Instant::now();
        if joined {
            self.stats.dedup_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.stats.misses.fetch_add(1, Ordering::Relaxed);
            match emit() {
                Ok((name, hlo)) => self.submit(Job { key, name, hlo, flight: flight.clone() }),
                Err(e) => self.fail_inflight(&key, &flight, format!("{e:#}")),
            }
        }
        let exe = flight.wait();
        let stall = t0.elapsed();
        self.stats.stall_ns.fetch_add(stall.as_nanos() as u64, Ordering::Relaxed);
        exe.map(|e| (e, Fetch { compiled: !joined, deduped: joined, stall }))
    }

    /// Speculatively warm `(sig, extents)`: if the key is neither resident
    /// nor in flight, enqueue its compile and return immediately. `emit`
    /// runs (on the calling thread — it is cheap string emission) only
    /// when a warm is actually enqueued. Never blocks on compilation.
    pub fn prefetch<F>(&self, sig: &str, extents: &[usize], emit: F)
    where
        F: FnOnce() -> Result<(String, String)>,
    {
        let key: StoreKey = (sig.to_string(), extents.to_vec());
        let flight = {
            let mut map = relock(&self.shards[shard_of(&key)]);
            if map.contains_key(&key) {
                return;
            }
            let f = Arc::new(Flight::new());
            map.insert(key.clone(), Slot::InFlight(f.clone()));
            f
        };
        self.stats.prefetches.fetch_add(1, Ordering::Relaxed);
        match emit() {
            Ok((name, hlo)) => self.submit(Job { key, name, hlo, flight }),
            Err(e) => self.fail_inflight(&key, &flight, format!("{e:#}")),
        }
    }

    /// Is the key resident (compiled and ready)? Used by tests and by the
    /// serving bench to verify warms landed.
    pub fn is_ready(&self, sig: &str, extents: &[usize]) -> bool {
        let key: StoreKey = (sig.to_string(), extents.to_vec());
        matches!(relock(&self.shards[shard_of(&key)]).get(&key), Some(Slot::Ready(_)))
    }

    /// Block until no lookup would stall: every in-flight compile (demand
    /// or prefetch) has resolved. Test/bench helper.
    pub fn quiesce(&self) {
        let flights: Vec<Arc<Flight>> = self
            .shards
            .iter()
            .flat_map(|s| {
                relock(s)
                    .values()
                    .filter_map(|slot| match slot {
                        Slot::InFlight(f) => Some(f.clone()),
                        Slot::Ready(_) => None,
                    })
                    .collect::<Vec<_>>()
            })
            .collect();
        for f in flights {
            let _ = f.wait();
        }
    }

    pub fn snapshot(&self) -> StoreSnapshot {
        let entries = self.shards.iter().map(|s| relock(s).len()).sum();
        StoreSnapshot {
            hits: self.stats.hits.load(Ordering::Relaxed),
            misses: self.stats.misses.load(Ordering::Relaxed),
            dedup_hits: self.stats.dedup_hits.load(Ordering::Relaxed),
            prefetches: self.stats.prefetches.load(Ordering::Relaxed),
            stall: Duration::from_nanos(self.stats.stall_ns.load(Ordering::Relaxed)),
            compile_time: Duration::from_nanos(self.stats.compile_ns.load(Ordering::Relaxed)),
            entries,
        }
    }
}

const _: fn() = || {
    fn ok<T: Send + Sync>() {}
    ok::<KernelStore>();
};

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Barrier;

    const HLO: &str = "HloModule t, entry_computation_layout={(f32[4]{0})->f32[4]{0}}\n\n\
         ENTRY main {\n  p0 = f32[4]{0} parameter(0)\n  ROOT t = f32[4]{0} tanh(p0)\n}\n";

    fn store() -> Arc<KernelStore> {
        Arc::new(KernelStore::new(Arc::new(Device::cpu().unwrap())))
    }

    #[test]
    fn compiles_once_and_hits_after() {
        let s = store();
        let (e1, f1) = s
            .get_or_compile("t:test", &[4], || Ok(("k".into(), HLO.into())))
            .unwrap();
        assert!(f1.compiled);
        let (e2, f2) = s
            .get_or_compile("t:test", &[4], || panic!("must not re-emit"))
            .unwrap();
        assert!(!f2.compiled && !f2.deduped);
        assert_eq!(f2.stall, Duration::ZERO, "ready hit never stalls");
        assert!(Arc::ptr_eq(&e1, &e2));
        let snap = s.snapshot();
        assert_eq!((snap.misses, snap.hits, snap.entries), (1, 1, 1));
    }

    #[test]
    fn concurrent_misses_single_flight() {
        // M threads race one key: exactly one compile, M-1 joins/hits.
        const M: usize = 4;
        let s = store();
        let barrier = Arc::new(Barrier::new(M));
        let handles: Vec<_> = (0..M)
            .map(|_| {
                let s = s.clone();
                let b = barrier.clone();
                std::thread::spawn(move || {
                    b.wait();
                    let (_, f) = s
                        .get_or_compile("t:race", &[8], || Ok(("k".into(), HLO.into())))
                        .unwrap();
                    f
                })
            })
            .collect();
        let fetches: Vec<Fetch> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(fetches.iter().filter(|f| f.compiled).count(), 1, "exactly one compile");
        let snap = s.snapshot();
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.dedup_hits + snap.hits, (M - 1) as u64);
        assert_eq!(snap.entries, 1);
    }

    #[test]
    fn prefetch_overlaps_and_demand_hits() {
        let s = store();
        s.prefetch("t:warm", &[16], || Ok(("warm".into(), HLO.into())));
        s.quiesce();
        assert!(s.is_ready("t:warm", &[16]));
        let (_, f) = s
            .get_or_compile("t:warm", &[16], || panic!("prefetched key must not re-emit"))
            .unwrap();
        assert!(!f.compiled);
        assert_eq!(f.stall, Duration::ZERO, "warmed key is stall-free");
        let snap = s.snapshot();
        assert_eq!(snap.prefetches, 1);
        assert_eq!(snap.misses, 0, "prefetch is not a demand miss");
        // A second prefetch of a resident key is a no-op.
        s.prefetch("t:warm", &[16], || panic!("resident key must not re-emit"));
        assert_eq!(s.snapshot().prefetches, 1);
    }

    #[test]
    fn failed_flight_broadcasts_to_all_waiters_then_retry_succeeds() {
        use crate::runtime::faults::FaultPlan;
        // A device that fails exactly the first compile it is asked for:
        // whichever racer owns the flight, every joiner must see the error.
        const M: usize = 4;
        let plan = Arc::new(FaultPlan::parse("seed=2,compile=1000:1").unwrap());
        let s = Arc::new(KernelStore::new(Arc::new(
            Device::cpu_with_faults(Some(plan.clone())).unwrap(),
        )));
        let barrier = Arc::new(Barrier::new(M));
        let handles: Vec<_> = (0..M)
            .map(|_| {
                let s = s.clone();
                let b = barrier.clone();
                std::thread::spawn(move || {
                    b.wait();
                    s.get_or_compile("t:flaky", &[4], || Ok(("k".into(), HLO.into())))
                        .map(|_| ())
                        .map_err(|e| format!("{e:#}"))
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        let errs = results.iter().filter(|r| r.is_err()).count();
        assert!(errs >= 1, "the owner must see the injected failure");
        for r in results.iter().filter(|r| r.is_err()) {
            let msg = r.as_ref().unwrap_err();
            assert!(msg.contains("injected compile fault"), "{msg}");
        }
        // Losers that arrived after the failed slot was dropped may have
        // won a fresh (successful) compile; either way the key must now be
        // compilable — the failed slot never pins the store.
        let _ = s
            .get_or_compile("t:flaky", &[4], || Ok(("k".into(), HLO.into())))
            .unwrap();
        assert!(s.is_ready("t:flaky", &[4]));
        assert_eq!(plan.fired(crate::runtime::faults::FaultSite::Compile), 1);
    }

    #[test]
    fn mid_compile_panic_cannot_wedge_pending() {
        use crate::runtime::faults::{FaultPlan, FaultSite};
        let plan = Arc::new(FaultPlan::parse("seed=3,compile-panic=1000:1").unwrap());
        let s = Arc::new(KernelStore::new(Arc::new(
            Device::cpu_with_faults(Some(plan.clone())).unwrap(),
        )));
        // The pool thread panics mid-compile: the drop guard must fail the
        // flight (not leave it Pending) and clear the slot.
        let err = s
            .get_or_compile("t:boom", &[4], || Ok(("k".into(), HLO.into())))
            .unwrap_err();
        assert!(format!("{err:#}").contains("panicked mid-compile"), "{err:#}");
        assert!(!s.is_ready("t:boom", &[4]));
        assert_eq!(plan.fired(FaultSite::CompilePanic), 1);
        // The pool survives the panic and the retry compiles clean.
        let (_, f) = s
            .get_or_compile("t:boom", &[4], || Ok(("k".into(), HLO.into())))
            .unwrap();
        assert!(f.compiled);
        assert!(s.is_ready("t:boom", &[4]));
    }

    #[test]
    fn compile_errors_propagate_and_allow_retry() {
        let s = store();
        let err = s.get_or_compile("t:bad", &[4], || Ok(("bad".into(), "not hlo".into())));
        assert!(err.is_err());
        // The failed slot was dropped: a corrected emit succeeds.
        let ok = s.get_or_compile("t:bad", &[4], || Ok(("good".into(), HLO.into())));
        assert!(ok.is_ok());
        // Emit failure resolves waiters too.
        let err2 = s.get_or_compile("t:bad2", &[4], || anyhow::bail!("no emitter"));
        assert!(err2.is_err());
    }
}
