//! Traffic-adaptive bucket policy: extent histograms, padded-FLOP-minimizing
//! boundary derivation, and the epoch-stamped hot-swap switch.
//!
//! The static [`BucketPolicy`](crate::codegen::BucketPolicy) enum picks the
//! bucket for a dynamic extent with a fixed rule (`NextPow2`,
//! `MultipleOf(k)`, …) chosen at compile time. Under skewed real traffic a
//! fixed rule pays padding for headroom most requests never use: a Zipf
//! stream of sequence lengths clustered at 40 pads every one of them to 64
//! under `NextPow2`. This module makes the policy a *runtime object* derived
//! from observed traffic (Nimble's shape-function dispatch over a kernel
//! family, arXiv 2006.03031; Vortex's strategy selection from observed
//! shape distributions, arXiv 2409.01075):
//!
//! * [`ExtentHistogram`] — a mutex-guarded (tiny critical section — one
//!   `BTreeMap` bump) per-symbol extent histogram every dispatch records
//!   into, plus a capped map of *launch sites* (program id, fused-launch
//!   index, actual extent vectors) the interpret tier records so a
//!   re-bucketing pass knows exactly which kernels to pre-warm.
//! * [`derive_boundaries`] — an O(m²·K) dynamic program over the observed
//!   extents of each symbol: pick ≤K cut points (floored at
//!   hardware-friendly [`CUT_ALIGN`] multiples) minimizing the expected
//!   padded element count Σ count·(cut(e) − e).
//! * [`Boundaries`] — the derived policy: sorted per-symbol cuts, an extent
//!   buckets to the first cut ≥ it and falls back to the base
//!   `BucketPolicy` beyond the largest cut (so every extent always has a
//!   bucket, including symbols never observed).
//! * [`PolicySwitch`] — the shared, versioned handle: an atomic
//!   [`PolicyEpoch`] plus the current `Arc<Boundaries>`. Workers read the
//!   epoch per dispatch (one `Acquire` load; the `KernelCache` re-snapshots
//!   only on a mismatch), launch-plan keys embed it so stale-epoch plans
//!   retire through the existing FIFO, and [`PolicySwitch::install`] flips
//!   it only after the new bucket family is compiled — a zero-stall swap.

use crate::codegen::BucketPolicy;
use crate::shape::SymId;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotone policy version. Epoch 0 is the compile-time base policy; every
/// [`PolicySwitch::install`] bumps it.
pub type PolicyEpoch = u64;

/// Hardware-friendly floor for derived cut points: cuts above this are
/// rounded up to a multiple of it (vector-lane/tile alignment); extents at
/// or below it keep exact cuts (rounding 3 up to 8 would *add* padding the
/// static policies don't pay).
pub const CUT_ALIGN: usize = 8;

/// Cap on distinct launch sites tracked for pre-warming (per histogram).
const SITES_CAP: usize = 256;

/// Cap on distinct actual-extent vectors tracked per launch site.
const SITE_ACTUALS_CAP: usize = 64;

/// A derived bucket policy: sorted cut points per symbol. An extent buckets
/// to the first cut ≥ it; extents beyond the largest cut (and symbols with
/// no cuts at all) fall back to the base [`BucketPolicy`], so the mapping is
/// total and monotone for every symbol.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Boundaries {
    pub base: BucketPolicy,
    /// Sorted ascending, non-empty per entry.
    pub cuts: BTreeMap<SymId, Vec<usize>>,
}

impl Boundaries {
    /// The epoch-0 policy: no cuts, every extent buckets through `base`.
    pub fn empty(base: BucketPolicy) -> Boundaries {
        Boundaries { base, cuts: BTreeMap::new() }
    }

    /// No derived cuts — behaves exactly like the base policy.
    pub fn is_trivial(&self) -> bool {
        self.cuts.is_empty()
    }

    /// Bucket `n` for `sym`: first cut ≥ `n`, else the base policy.
    pub fn bucket(&self, sym: SymId, n: usize) -> usize {
        let n = n.max(1);
        if let Some(cuts) = self.cuts.get(&sym) {
            let i = cuts.partition_point(|&c| c < n);
            if let Some(&c) = cuts.get(i) {
                return c;
            }
        }
        self.base.bucket(n)
    }

    /// Smallest bucket ≥ `n` any symbol's cuts can produce (base fallback
    /// when none can). Used by growth targets that are not tied to one
    /// symbol (e.g. `KvCache::grow`); always ≥ `n`, so growth progresses.
    pub fn bucket_any(&self, n: usize) -> usize {
        let n = n.max(1);
        self.cuts
            .values()
            .filter_map(|cuts| {
                let i = cuts.partition_point(|&c| c < n);
                cuts.get(i).copied()
            })
            .min()
            .unwrap_or_else(|| self.base.bucket(n))
    }

    /// Total number of cut points across all symbols (observability).
    pub fn cut_count(&self) -> usize {
        self.cuts.values().map(|v| v.len()).sum()
    }
}

#[derive(Default)]
struct HistInner {
    /// Per-symbol extent counts: `per_sym[s][e]` = dispatches observing
    /// extent `e` for symbol `s`.
    per_sym: BTreeMap<SymId, BTreeMap<usize, u64>>,
    /// Launch sites seen by the interpret tier: (program id, fused index)
    /// → distinct actual extent vectors. Capped; used to pre-warm the new
    /// bucket family before an epoch flip.
    sites: HashMap<(u64, usize), HashMap<Vec<usize>, u64>>,
    /// Total binding records (dispatch count proxy).
    total: u64,
}

/// An immutable copy of the histogram state (sorted, for determinism).
#[derive(Debug, Clone, Default)]
pub struct HistSnapshot {
    /// Per symbol: sorted `(extent, count)` bins.
    pub per_sym: Vec<(SymId, Vec<(usize, u64)>)>,
    /// Per launch site `(program id, fused index)`: distinct actual extent
    /// vectors, sorted.
    pub sites: Vec<((u64, usize), Vec<Vec<usize>>)>,
    /// Total binding records folded in.
    pub total: u64,
}

/// Shared traffic histogram. One mutex with a tiny critical section (a
/// couple of map bumps) — dispatch rates here are request-granular, not
/// per-element, so a short lock beats the complexity of sharded atomics.
#[derive(Default)]
pub struct ExtentHistogram {
    inner: Mutex<HistInner>,
}

impl ExtentHistogram {
    pub fn new() -> ExtentHistogram {
        ExtentHistogram::default()
    }

    /// Record one dispatch's binding vector (canonical symbol → extent).
    pub fn record_bindings(&self, bindings: &[(SymId, i64)]) {
        if bindings.is_empty() {
            return;
        }
        let mut h = self.inner.lock().unwrap();
        for &(s, v) in bindings {
            if v > 0 {
                *h.per_sym.entry(s).or_default().entry(v as usize).or_insert(0) += 1;
            }
        }
        h.total += 1;
    }

    /// Record one symbol/extent observation (batched dispatches record the
    /// per-member batch-symbol extent this way).
    pub fn record_extent(&self, sym: SymId, extent: usize) {
        if extent == 0 {
            return;
        }
        let mut h = self.inner.lock().unwrap();
        *h.per_sym.entry(sym).or_default().entry(extent).or_insert(0) += 1;
        h.total += 1;
    }

    /// Record a fused-launch site: the actual extents `actual` of `syms` at
    /// fused launch `fused` of program `program`. Also folds the extents
    /// into the per-symbol bins so *derived* symbols (which never appear in
    /// binding vectors) get cuts too. Only the interpret tier records sites
    /// — replays skip it — so the site map tracks the distinct shape set,
    /// not traffic frequency (frequency lives in the binding bins).
    pub fn record_site(&self, program: u64, fused: usize, syms: &[SymId], actual: &[usize]) {
        let mut h = self.inner.lock().unwrap();
        for (&s, &a) in syms.iter().zip(actual) {
            if a > 0 {
                *h.per_sym.entry(s).or_default().entry(a).or_insert(0) += 1;
            }
        }
        let key = (program, fused);
        if h.sites.len() >= SITES_CAP && !h.sites.contains_key(&key) {
            return;
        }
        let per_site = h.sites.entry(key).or_default();
        if per_site.len() >= SITE_ACTUALS_CAP && !per_site.contains_key(actual) {
            return;
        }
        *per_site.entry(actual.to_vec()).or_insert(0) += 1;
    }

    /// Total binding records so far (cheap re-bucketing trigger check).
    pub fn total(&self) -> u64 {
        self.inner.lock().unwrap().total
    }

    /// Sorted, immutable copy of the current state.
    pub fn snapshot(&self) -> HistSnapshot {
        let h = self.inner.lock().unwrap();
        let per_sym = h
            .per_sym
            .iter()
            .map(|(&s, bins)| (s, bins.iter().map(|(&e, &c)| (e, c)).collect()))
            .collect();
        let mut sites: Vec<((u64, usize), Vec<Vec<usize>>)> = h
            .sites
            .iter()
            .map(|(&k, actuals)| {
                let mut v: Vec<Vec<usize>> = actuals.keys().cloned().collect();
                v.sort_unstable();
                (k, v)
            })
            .collect();
        sites.sort_unstable_by_key(|&(k, _)| k);
        HistSnapshot { per_sym, sites, total: h.total }
    }
}

/// The shared, versioned policy handle: base policy, current derived
/// [`Boundaries`], the traffic [`ExtentHistogram`], and the atomic epoch.
/// One `PolicySwitch` is shared (via `Arc`) by every executor forked from a
/// compiled model, so the histogram aggregates across workers and a swap is
/// observed by all of them on their next dispatch.
pub struct PolicySwitch {
    base: BucketPolicy,
    epoch: AtomicU64,
    current: Mutex<Arc<Boundaries>>,
    pub histogram: ExtentHistogram,
    swaps: AtomicU64,
}

impl PolicySwitch {
    /// Epoch 0: the trivial boundaries (pure base policy).
    pub fn new(base: BucketPolicy) -> PolicySwitch {
        PolicySwitch {
            base,
            epoch: AtomicU64::new(0),
            current: Mutex::new(Arc::new(Boundaries::empty(base))),
            histogram: ExtentHistogram::new(),
            swaps: AtomicU64::new(0),
        }
    }

    pub fn base(&self) -> BucketPolicy {
        self.base
    }

    /// Current epoch (one `Acquire` load — the per-dispatch fast path).
    pub fn epoch(&self) -> PolicyEpoch {
        self.epoch.load(Ordering::Acquire)
    }

    /// Consistent (epoch, boundaries) pair.
    pub fn snapshot(&self) -> (PolicyEpoch, Arc<Boundaries>) {
        let cur = self.current.lock().unwrap();
        (self.epoch.load(Ordering::Acquire), cur.clone())
    }

    /// Flip to `next` and bump the epoch. Callers must have pre-compiled
    /// the new bucket family first (see `Executor::rebucket`) — the switch
    /// itself is just the atomic publish.
    pub fn install(&self, next: Boundaries) -> PolicyEpoch {
        let mut cur = self.current.lock().unwrap();
        *cur = Arc::new(next);
        self.swaps.fetch_add(1, Ordering::Relaxed);
        self.epoch.fetch_add(1, Ordering::Release) + 1
    }

    /// Number of installs so far.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::Relaxed)
    }
}

/// Round a cut candidate up to the hardware-friendly floor (exact below
/// [`CUT_ALIGN`] — see the constant's docs).
fn align_cut(e: usize) -> usize {
    if e <= CUT_ALIGN {
        e
    } else {
        e.div_ceil(CUT_ALIGN) * CUT_ALIGN
    }
}

/// Derive ≤`max_cuts` bucket boundaries per symbol from the observed
/// extent histogram, minimizing the expected padded element count
/// Σ count·(cut(e) − e) per symbol (the padded-FLOP proxy: padding scales
/// multiplicatively with the other dims, identically for every candidate
/// cut set). The largest observed extent's candidate is always chosen so
/// all observed traffic is covered; everything beyond it falls back to the
/// base policy.
pub fn derive_boundaries(snap: &HistSnapshot, max_cuts: usize, base: BucketPolicy) -> Boundaries {
    let mut cuts = BTreeMap::new();
    for (sym, bins) in &snap.per_sym {
        let c = derive_cuts(bins, max_cuts);
        if !c.is_empty() {
            cuts.insert(*sym, c);
        }
    }
    Boundaries { base, cuts }
}

/// One symbol's DP: aggregate extents into aligned candidates, then pick
/// ≤`max_cuts` of them minimizing Σ count·(cut(e) − e). O(m²·K) with
/// prefix sums; m is the number of distinct aligned extents (small — real
/// traffic clusters).
fn derive_cuts(bins: &[(usize, u64)], max_cuts: usize) -> Vec<usize> {
    // Aggregate: candidate cut → (total count, count-weighted extent sum).
    let mut agg: BTreeMap<usize, (u64, u64)> = BTreeMap::new();
    for &(e, w) in bins {
        if e == 0 || w == 0 {
            continue;
        }
        let c = align_cut(e);
        let ent = agg.entry(c).or_insert((0, 0));
        ent.0 += w;
        ent.1 += w * e as u64;
    }
    if agg.is_empty() {
        return Vec::new();
    }
    let cands: Vec<(usize, u64, u64)> = agg.iter().map(|(&c, &(w, s))| (c, w, s)).collect();
    let m = cands.len();
    let k = max_cuts.max(1).min(m);
    if m <= k {
        // Every observed (aligned) extent gets its own cut: zero padding
        // beyond the alignment floor.
        return cands.iter().map(|&(c, _, _)| c).collect();
    }
    // Prefix sums over candidates: W[i] = Σ counts of cands[..i], S[i] =
    // Σ count·extent of cands[..i]. Covering cands[i..=j] with a cut at
    // cands[j] costs cands[j].0·(W[j+1]−W[i]) − (S[j+1]−S[i]).
    let mut wsum = vec![0u64; m + 1];
    let mut ssum = vec![0u64; m + 1];
    for (i, &(_, w, s)) in cands.iter().enumerate() {
        wsum[i + 1] = wsum[i] + w;
        ssum[i + 1] = ssum[i] + s;
    }
    let cost = |i: usize, j: usize| -> u64 {
        cands[j].0 as u64 * (wsum[j + 1] - wsum[i]) - (ssum[j + 1] - ssum[i])
    };
    const INF: u64 = u64::MAX / 2;
    // dp[j] after layer t = min padding covering cands[0..=j] with exactly
    // t+1 cuts, the last at j; parents[t][j] = index of the previous cut.
    // Exactly-k is the ≤k optimum: splitting any multi-candidate segment
    // never increases cost, and m > k guarantees room to split.
    let mut dp: Vec<u64> = (0..m).map(|j| cost(0, j)).collect();
    let mut parents: Vec<Vec<usize>> = vec![vec![usize::MAX; m]];
    for _ in 1..k {
        let mut next = vec![INF; m];
        let mut parent = vec![usize::MAX; m];
        for j in 1..m {
            for i in 0..j {
                if dp[i] >= INF {
                    continue;
                }
                let c = dp[i] + cost(i + 1, j);
                if c < next[j] {
                    next[j] = c;
                    parent[j] = i;
                }
            }
        }
        dp = next;
        parents.push(parent);
    }
    // The last candidate is always covered by its own cut (anything less
    // would push the largest observed extents to the base fallback —
    // exactly the padding we are trying to shed).
    let mut chosen = Vec::new();
    let mut j = m - 1;
    for t in (0..parents.len()).rev() {
        chosen.push(cands[j].0);
        let p = parents[t][j];
        if p == usize::MAX {
            break;
        }
        j = p;
    }
    chosen.sort_unstable();
    chosen.dedup();
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sym(n: u32) -> SymId {
        SymId(n)
    }

    #[test]
    fn boundaries_bucket_first_cut_then_base_fallback() {
        let mut cuts = BTreeMap::new();
        cuts.insert(sym(0), vec![16, 40, 96]);
        let b = Boundaries { base: BucketPolicy::NextPow2, cuts };
        assert_eq!(b.bucket(sym(0), 1), 16);
        assert_eq!(b.bucket(sym(0), 16), 16);
        assert_eq!(b.bucket(sym(0), 17), 40);
        assert_eq!(b.bucket(sym(0), 96), 96);
        // Beyond the largest cut and for unknown symbols: base policy.
        assert_eq!(b.bucket(sym(0), 97), 128);
        assert_eq!(b.bucket(sym(1), 9), 16);
    }

    #[test]
    fn bucket_any_takes_min_cut_over_symbols_and_progresses() {
        let mut cuts = BTreeMap::new();
        cuts.insert(sym(0), vec![32, 64]);
        cuts.insert(sym(1), vec![24, 80]);
        let b = Boundaries { base: BucketPolicy::NextPow2, cuts };
        assert_eq!(b.bucket_any(10), 24);
        assert_eq!(b.bucket_any(33), 64);
        assert_eq!(b.bucket_any(81), 128, "past every cut: base fallback");
        for n in 1..200usize {
            assert!(b.bucket_any(n) >= n, "bucket_any({n}) must not shrink");
        }
    }

    #[test]
    fn derive_gives_each_aligned_extent_a_cut_when_under_budget() {
        let bins = vec![(9usize, 5u64), (40, 3), (96, 1)];
        let cuts = derive_cuts(&bins, 8);
        assert_eq!(cuts, vec![16, 40, 96], "aligned to CUT_ALIGN, all covered");
    }

    #[test]
    fn derive_keeps_exact_cuts_below_the_alignment_floor() {
        let bins = vec![(3usize, 10u64), (5, 4)];
        let cuts = derive_cuts(&bins, 4);
        assert_eq!(cuts, vec![3, 5], "tiny extents keep exact cuts");
    }

    #[test]
    fn derive_dp_minimizes_weighted_padding_under_cut_budget() {
        // Heavy cluster at 9..=12 (aligned 16), light outlier at 100
        // (aligned 104). K=1 must cover everything with one cut at 104;
        // K=2 splits so the heavy cluster stops padding to 104.
        let bins =
            vec![(9usize, 100u64), (10, 100), (11, 100), (12, 100), (100, 1)];
        assert_eq!(derive_cuts(&bins, 1), vec![104]);
        assert_eq!(derive_cuts(&bins, 2), vec![16, 104]);
    }

    #[test]
    fn derive_respects_frequency_weights() {
        // Three aligned candidates (16, 48, 104), budget 2: the cut that
        // merges must sacrifice the *lightest* cluster.
        let bins = vec![(16usize, 1u64), (48, 1000), (100, 1000)];
        let cuts = derive_cuts(&bins, 2);
        // Merging 16 into 48 costs 1·32; merging 48 into 104 costs
        // 1000·56. The DP must pick {48, 104}.
        assert_eq!(cuts, vec![48, 104]);
    }

    #[test]
    fn histogram_records_and_snapshots() {
        let h = ExtentHistogram::new();
        h.record_bindings(&[(sym(0), 9), (sym(1), 4)]);
        h.record_bindings(&[(sym(0), 9)]);
        h.record_extent(sym(0), 40);
        h.record_site(7, 2, &[sym(5)], &[33]);
        let snap = h.snapshot();
        assert_eq!(snap.total, 3);
        let s0 = &snap.per_sym.iter().find(|(s, _)| *s == sym(0)).unwrap().1;
        assert_eq!(s0.as_slice(), &[(9, 2), (40, 1)]);
        // Site recording also feeds per-symbol bins (derived symbols).
        assert!(snap.per_sym.iter().any(|(s, _)| *s == sym(5)));
        assert_eq!(snap.sites, vec![((7, 2), vec![vec![33]])]);
    }

    #[test]
    fn switch_install_bumps_epoch_and_swap_count() {
        let sw = PolicySwitch::new(BucketPolicy::NextPow2);
        assert_eq!(sw.epoch(), 0);
        let (e0, b0) = sw.snapshot();
        assert_eq!(e0, 0);
        assert!(b0.is_trivial());
        let mut cuts = BTreeMap::new();
        cuts.insert(sym(0), vec![40]);
        let e1 = sw.install(Boundaries { base: sw.base(), cuts });
        assert_eq!(e1, 1);
        assert_eq!(sw.epoch(), 1);
        assert_eq!(sw.swaps(), 1);
        let (e, b) = sw.snapshot();
        assert_eq!(e, 1);
        assert_eq!(b.bucket(sym(0), 20), 40);
    }

    #[test]
    fn derive_boundaries_covers_only_observed_symbols() {
        let h = ExtentHistogram::new();
        for _ in 0..10 {
            h.record_bindings(&[(sym(0), 40)]);
        }
        let b = derive_boundaries(&h.snapshot(), 4, BucketPolicy::NextPow2);
        assert_eq!(b.bucket(sym(0), 33), 40, "observed symbol gets a cut");
        assert_eq!(b.bucket(sym(9), 33), 64, "unobserved symbol: base");
    }
}
