//! The compiled-kernel cache.
//!
//! DISC's cache is keyed by *shape-agnostic pattern signature* plus bucket
//! extents; the XLA-like static pipeline uses the same cache with
//! [`crate::codegen::BucketPolicy::Exact`], which degenerates the key to
//! one entry per concrete shape — reproducing the §2 compilation-overhead
//! pathology that the `compile_overhead` bench measures.

use crate::codegen::hlo::{emit_group, group_syms, KernelSpec};
use crate::codegen::BucketPolicy;
use crate::dhlo::Module;
use crate::fusion::{signature::signature, FusionGroup};
use crate::runtime::pjrt::{Device, Executable};
use crate::shape::SymId;
use anyhow::Result;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Duration;

/// A compiled fusion kernel plus its launch metadata.
pub struct CompiledKernel {
    pub spec: KernelSpec,
    pub exe: Executable,
}

/// Cache statistics (compilation overhead accounting).
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub compile_time: Duration,
    pub entries: usize,
}

/// Kernel cache over one device.
pub struct KernelCache {
    device: Rc<Device>,
    policy: BucketPolicy,
    map: HashMap<(String, Vec<usize>), Rc<CompiledKernel>>,
    pub stats: CacheStats,
}

impl KernelCache {
    pub fn new(device: Rc<Device>, policy: BucketPolicy) -> Self {
        KernelCache { device, policy, map: HashMap::new(), stats: CacheStats::default() }
    }

    pub fn policy(&self) -> BucketPolicy {
        self.policy
    }

    /// Look up (or compile) the kernel for `group` given the *actual*
    /// extents of its dynamic symbols. Returns the kernel and the bucketed
    /// extents used.
    pub fn get_or_compile(
        &mut self,
        m: &Module,
        g: &FusionGroup,
        sig: &str,
        actual: &HashMap<crate::shape::SymId, usize>,
    ) -> Result<(Rc<CompiledKernel>, HashMap<SymId, usize>)> {
        let syms = group_syms(m, g);
        let mut bucketed: HashMap<crate::shape::SymId, usize> = HashMap::with_capacity(syms.len());
        let mut key_dims = Vec::with_capacity(syms.len());
        for s in &syms {
            let a = *actual
                .get(s)
                .ok_or_else(|| anyhow::anyhow!("missing actual extent for {s}"))?;
            let bk = self.policy.bucket(a);
            bucketed.insert(*s, bk);
            key_dims.push(bk);
        }
        let key = (sig.to_string(), key_dims);
        if let Some(k) = self.map.get(&key) {
            self.stats.hits += 1;
            return Ok((k.clone(), bucketed));
        }
        self.stats.misses += 1;
        let name = format!("fusion_{}", self.map.len());
        let spec = emit_group(m, g, &bucketed, &name)?;
        let exe = self.device.compile_hlo_text_named(&name, &spec.hlo)?;
        self.stats.compile_time += exe.compile_time;
        let k = Rc::new(CompiledKernel { spec, exe });
        self.map.insert(key, k.clone());
        self.stats.entries = self.map.len();
        Ok((k, bucketed))
    }

    /// Convenience: signature + lookup in one call (used by tests; the
    /// executor precomputes signatures at compile time).
    pub fn get_for(
        &mut self,
        m: &Module,
        g: &FusionGroup,
        actual: &HashMap<crate::shape::SymId, usize>,
    ) -> Result<(Rc<CompiledKernel>, HashMap<SymId, usize>)> {
        let sig = signature(m, g);
        self.get_or_compile(m, g, &sig, actual)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::fusion::{plan, FusionOptions};

    fn chain() -> Module {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let t = b.unary(UnKind::Tanh, x);
        let y = b.add(x, t).unwrap();
        b.finish(vec![y])
    }

    #[test]
    fn bucket_cache_no_recompilation_within_bucket() {
        let m = chain();
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let dev = Rc::new(Device::cpu().unwrap());
        let mut cache = KernelCache::new(dev, BucketPolicy::NextPow2);
        let syms = group_syms(&m, g);
        // Shapes 5, 6, 7, 8 all land in bucket 8: one compile, three hits.
        for n in [5usize, 6, 7, 8] {
            let actual: HashMap<SymId, usize> = syms.iter().map(|&s| (s, n)).collect();
            cache.get_for(&m, g, &actual).unwrap();
        }
        assert_eq!(cache.stats.misses, 1);
        assert_eq!(cache.stats.hits, 3);
        // Shape 9 needs bucket 16: one more compile.
        let actual: HashMap<SymId, usize> = syms.iter().map(|&s| (s, 9)).collect();
        cache.get_for(&m, g, &actual).unwrap();
        assert_eq!(cache.stats.misses, 2);
    }

    #[test]
    fn exact_policy_recompiles_per_shape() {
        let m = chain();
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let dev = Rc::new(Device::cpu().unwrap());
        let mut cache = KernelCache::new(dev, BucketPolicy::Exact);
        let syms = group_syms(&m, g);
        for n in [5usize, 6, 7, 8] {
            let actual: HashMap<SymId, usize> = syms.iter().map(|&s| (s, n)).collect();
            cache.get_for(&m, g, &actual).unwrap();
        }
        assert_eq!(cache.stats.misses, 4, "static pipeline compiles per shape");
        assert_eq!(cache.stats.hits, 0);
    }

    #[test]
    fn same_pattern_shares_cache_across_modules() {
        // Two structurally identical modules share cache entries: the
        // signature is shape- and identity-agnostic.
        let m1 = chain();
        let m2 = chain();
        let p1 = plan(&m1, &FusionOptions::default());
        let p2 = plan(&m2, &FusionOptions::default());
        let dev = Rc::new(Device::cpu().unwrap());
        let mut cache = KernelCache::new(dev, BucketPolicy::NextPow2);
        let syms1 = group_syms(&m1, &p1.groups[0]);
        let actual1: HashMap<SymId, usize> = syms1.iter().map(|&s| (s, 7)).collect();
        cache.get_for(&m1, &p1.groups[0], &actual1).unwrap();
        let syms2 = group_syms(&m2, &p2.groups[0]);
        let actual2: HashMap<SymId, usize> = syms2.iter().map(|&s| (s, 8)).collect();
        cache.get_for(&m2, &p2.groups[0], &actual2).unwrap();
        assert_eq!(cache.stats.misses, 1);
        assert_eq!(cache.stats.hits, 1);
    }
}
