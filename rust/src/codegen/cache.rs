//! The per-worker kernel-cache handle over the shared [`KernelStore`].
//!
//! DISC's cache is keyed by *shape-agnostic pattern signature* plus bucket
//! extents; the XLA-like static pipeline uses the same cache with
//! [`crate::codegen::BucketPolicy::Exact`], which degenerates the key to
//! one entry per concrete shape — reproducing the §2 compilation-overhead
//! pathology that the `compile_overhead` bench measures.
//!
//! Since the multi-worker refactor the compiled executables live in the
//! process-wide, shard-locked [`KernelStore`] (shared across executor
//! workers and across models compiled by one `DiscCompiler`); a
//! `KernelCache` is one worker's *handle*: it memoizes the kernels it has
//! already fetched — hot-path lookups touch no lock at all — and keeps
//! per-worker [`CacheStats`] so `RunMetrics` deltas stay attributable to
//! the run that caused them. Each pattern×bucket therefore compiles
//! exactly once per process, whichever worker touches it first; everyone
//! else gets a `shared_hit` (already resident) or a `dedup_hit` (joined
//! the in-flight compile).

use crate::codegen::hlo::{emit_group, group_syms, KernelSpec};
use crate::codegen::policy::{Boundaries, PolicyEpoch, PolicySwitch};
use crate::codegen::store::KernelStore;
use crate::codegen::BucketPolicy;
use crate::dhlo::Module;
use crate::fusion::{signature::signature, FusionGroup};
use crate::runtime::pjrt::{Device, Executable};
use crate::shape::SymId;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

/// Namespace prefix for fused-kernel keys in the shared store (the GEMM
/// library uses `lib:`-prefixed signatures in the same store).
const FUSED_NS: &str = "fused:";

/// A compiled fusion kernel plus its launch metadata. The executable is
/// process-shared; the spec (input dims, extent locals, output shape) is
/// re-derived per handle — it is cheap, deterministic string/metadata
/// emission, and keeping it per-handle lets launch plans hold plain
/// `Arc<CompiledKernel>` without locking.
pub struct CompiledKernel {
    pub spec: KernelSpec,
    pub exe: Arc<Executable>,
}

/// Per-handle cache statistics (compilation-overhead accounting for one
/// worker). `misses` counts compiles *this handle initiated* — the counter
/// behind `RunMetrics::compile_events`; kernels another worker compiled
/// show up as `shared_hits`/`dedup_hits` instead.
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    /// Served from this handle's local memo (no store lookup at all).
    /// Store-resident serves count as `shared_hits` instead.
    pub hits: u64,
    /// This handle initiated the compile.
    pub misses: u64,
    /// Resident in the shared store (another handle compiled it earlier).
    pub shared_hits: u64,
    /// Joined another worker's in-flight compile (single-flight).
    pub dedup_hits: u64,
    /// Time this handle spent blocked on the compile service.
    pub stall: Duration,
    pub compile_time: Duration,
    pub entries: usize,
}

/// One worker's kernel-cache handle.
pub struct KernelCache {
    store: Arc<KernelStore>,
    policy: BucketPolicy,
    /// The shared traffic-adaptive policy switch, when the executor serves
    /// under one. Bucket lookups consult its live [`Boundaries`]; with no
    /// switch (VM baseline, tests) the static `policy` decides alone.
    switch: Option<Arc<PolicySwitch>>,
    /// Epoch-cached snapshot of the switch's current boundaries: the hot
    /// path pays one atomic epoch load per dispatch and re-locks the switch
    /// only when a swap happened. Stale-epoch memo entries keep their old
    /// bucket keys — the kernels stay valid, they just stop being looked up
    /// once traffic moves to the new buckets.
    live: Option<(PolicyEpoch, Arc<Boundaries>)>,
    /// Local memo: keys this handle has resolved, with their spec. Lock-free
    /// on repeat lookups.
    map: HashMap<(String, Vec<usize>), Arc<CompiledKernel>>,
    pub stats: CacheStats,
}

impl KernelCache {
    /// Standalone cache over a private store (single-worker uses, tests,
    /// the VM baseline). Multi-worker serving shares one store via
    /// [`KernelCache::with_store`].
    pub fn new(device: Arc<Device>, policy: BucketPolicy) -> Self {
        Self::with_store(Arc::new(KernelStore::new(device)), policy)
    }

    /// A handle over a shared (process-wide) store.
    pub fn with_store(store: Arc<KernelStore>, policy: BucketPolicy) -> Self {
        KernelCache {
            store,
            policy,
            switch: None,
            live: None,
            map: HashMap::new(),
            stats: CacheStats::default(),
        }
    }

    pub fn policy(&self) -> BucketPolicy {
        self.policy
    }

    /// Attach the shared policy switch (executor setup and forks).
    pub fn set_switch(&mut self, switch: Arc<PolicySwitch>) {
        self.switch = Some(switch);
        self.live = None;
    }

    /// The live derived boundaries, re-snapshotted only when the epoch
    /// moved. `None` when no switch is attached or the boundaries are
    /// trivial (pure base policy) — the caller then uses `policy` directly.
    fn live_boundaries(&mut self) -> Option<Arc<Boundaries>> {
        let sw = self.switch.as_ref()?;
        let e = sw.epoch();
        let b = match &self.live {
            Some((le, b)) if *le == e => b.clone(),
            _ => {
                let (e, b) = sw.snapshot();
                self.live = Some((e, b.clone()));
                b
            }
        };
        if b.is_trivial() {
            None
        } else {
            Some(b)
        }
    }

    pub fn store(&self) -> &Arc<KernelStore> {
        &self.store
    }

    /// Resolve the bucketed extents of `g`'s symbols under the live policy
    /// (derived boundaries when a non-trivial epoch is installed, the
    /// static base policy otherwise).
    fn bucketed_extents(
        &mut self,
        syms: &[SymId],
        actual: &HashMap<SymId, usize>,
    ) -> Result<(HashMap<SymId, usize>, Vec<usize>)> {
        let live = self.live_boundaries();
        let mut bucketed: HashMap<SymId, usize> = HashMap::with_capacity(syms.len());
        let mut key_dims = Vec::with_capacity(syms.len());
        for s in syms {
            let a = *actual
                .get(s)
                .ok_or_else(|| anyhow::anyhow!("missing actual extent for {s}"))?;
            let bk = match &live {
                Some(b) => b.bucket(*s, a),
                None => self.policy.bucket(a),
            };
            bucketed.insert(*s, bk);
            key_dims.push(bk);
        }
        Ok((bucketed, key_dims))
    }

    /// Look up (or compile) the kernel for `group` given the *actual*
    /// extents of its dynamic symbols. Returns the kernel and the bucketed
    /// extents used.
    pub fn get_or_compile(
        &mut self,
        m: &Module,
        g: &FusionGroup,
        sig: &str,
        actual: &HashMap<SymId, usize>,
    ) -> Result<(Arc<CompiledKernel>, HashMap<SymId, usize>)> {
        let syms = group_syms(m, g);
        let (bucketed, key_dims) = self.bucketed_extents(&syms, actual)?;
        let key = (sig.to_string(), key_dims);
        if let Some(k) = self.map.get(&key) {
            self.stats.hits += 1;
            return Ok((k.clone(), bucketed));
        }
        // The spec is deterministic for (pattern, buckets): emit it locally,
        // fetch/compile the executable through the shared store.
        let name = kernel_name(sig, &key.1);
        let spec = emit_group(m, g, &bucketed, &name)?;
        let store_sig = format!("{FUSED_NS}{sig}");
        let hlo = spec.hlo.clone();
        let (exe, fetch) = self
            .store
            .get_or_compile(&store_sig, &key.1, move || Ok((name, hlo)))?;
        if fetch.compiled {
            self.stats.misses += 1;
            self.stats.compile_time += exe.compile_time;
        } else if fetch.deduped {
            self.stats.dedup_hits += 1;
        } else {
            self.stats.shared_hits += 1;
        }
        self.stats.stall += fetch.stall;
        let k = Arc::new(CompiledKernel { spec, exe });
        self.map.insert(key, k.clone());
        self.stats.entries = self.map.len();
        Ok((k, bucketed))
    }

    /// Speculatively warm the *next* bucket of each dynamic symbol of
    /// `group`, one symbol at a time (the other symbols stay at their
    /// current bucket): growing traffic moves one axis per step — a
    /// sequence length creeping up, a batch dimension widening — so the
    /// reachable neighbor keys are the single-axis advances, not the joint
    /// advance of every axis at once. The neighbor is what the *live*
    /// policy produces for the next extent past the current bucket — after
    /// a boundary swap the warms target the new cut family, never a bucket
    /// the live policy cannot produce. Emits each spec and enqueues the
    /// compile on the background pool. Never blocks; no-ops for fully
    /// static groups or keys already resident/in flight.
    pub fn prefetch_neighbor(
        &mut self,
        m: &Module,
        g: &FusionGroup,
        sig: &str,
        actual: &HashMap<SymId, usize>,
    ) -> Result<()> {
        let syms = group_syms(m, g);
        if syms.is_empty() {
            return Ok(());
        }
        let live = self.live_boundaries();
        let (bucketed, key_dims) = self.bucketed_extents(&syms, actual)?;
        let store_sig = format!("{FUSED_NS}{sig}");
        for (i, s) in syms.iter().enumerate() {
            let nb = match &live {
                Some(b) => b.bucket(*s, key_dims[i] + 1),
                None => self.policy.bucket(key_dims[i] + 1),
            };
            if nb == key_dims[i] {
                continue;
            }
            let mut neighbor = bucketed.clone();
            neighbor.insert(*s, nb);
            let mut neighbor_dims = key_dims.clone();
            neighbor_dims[i] = nb;
            let name = format!("warm_{}", kernel_name(sig, &neighbor_dims));
            self.store.prefetch(&store_sig, &neighbor_dims, move || {
                let spec = emit_group(m, g, &neighbor, &name)?;
                Ok((name, spec.hlo))
            });
        }
        Ok(())
    }

    /// Warm the kernel for `g` at the buckets a *candidate* policy (not
    /// necessarily installed yet) assigns to `actual` — the re-bucketing
    /// pass compiles the next epoch's whole bucket family through this
    /// before the switch flips, so the swap itself never stalls a dispatch.
    /// Emits inline, compiles on the background pool; no-ops when the key
    /// is already resident or in flight.
    pub fn prefetch_bucketed(
        &self,
        m: &Module,
        g: &FusionGroup,
        sig: &str,
        syms: &[SymId],
        actual: &[usize],
        bounds: &Boundaries,
    ) -> Result<()> {
        anyhow::ensure!(
            syms.len() == actual.len(),
            "prefetch_bucketed: {} syms vs {} extents",
            syms.len(),
            actual.len()
        );
        let mut bucketed: HashMap<SymId, usize> = HashMap::with_capacity(syms.len());
        let mut key_dims = Vec::with_capacity(syms.len());
        for (&s, &a) in syms.iter().zip(actual) {
            let bk = bounds.bucket(s, a);
            bucketed.insert(s, bk);
            key_dims.push(bk);
        }
        let store_sig = format!("{FUSED_NS}{sig}");
        let name = format!("re_{}", kernel_name(sig, &key_dims));
        self.store.prefetch(&store_sig, &key_dims, move || {
            let spec = emit_group(m, g, &bucketed, &name)?;
            Ok((name, spec.hlo))
        });
        Ok(())
    }

    /// Convenience: signature + lookup in one call (used by tests; the
    /// executor precomputes signatures at compile time).
    pub fn get_for(
        &mut self,
        m: &Module,
        g: &FusionGroup,
        actual: &HashMap<SymId, usize>,
    ) -> Result<(Arc<CompiledKernel>, HashMap<SymId, usize>)> {
        let sig = signature(m, g);
        self.get_or_compile(m, g, &sig, actual)
    }
}

/// Debuggable kernel name: signature prefix + bucket extents.
fn kernel_name(sig: &str, dims: &[usize]) -> String {
    let clean: String = sig
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .take(24)
        .collect();
    let d = dims.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x");
    format!("fusion_{clean}_{d}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::fusion::{plan, FusionOptions};

    fn chain() -> Module {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let t = b.unary(UnKind::Tanh, x);
        let y = b.add(x, t).unwrap();
        b.finish(vec![y])
    }

    #[test]
    fn bucket_cache_no_recompilation_within_bucket() {
        let m = chain();
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let dev = Arc::new(Device::cpu().unwrap());
        let mut cache = KernelCache::new(dev, BucketPolicy::NextPow2);
        let syms = group_syms(&m, g);
        // Shapes 5, 6, 7, 8 all land in bucket 8: one compile, three hits.
        for n in [5usize, 6, 7, 8] {
            let actual: HashMap<SymId, usize> = syms.iter().map(|&s| (s, n)).collect();
            cache.get_for(&m, g, &actual).unwrap();
        }
        assert_eq!(cache.stats.misses, 1);
        assert_eq!(cache.stats.hits, 3);
        // Shape 9 needs bucket 16: one more compile.
        let actual: HashMap<SymId, usize> = syms.iter().map(|&s| (s, 9)).collect();
        cache.get_for(&m, g, &actual).unwrap();
        assert_eq!(cache.stats.misses, 2);
    }

    #[test]
    fn exact_policy_recompiles_per_shape() {
        let m = chain();
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let dev = Arc::new(Device::cpu().unwrap());
        let mut cache = KernelCache::new(dev, BucketPolicy::Exact);
        let syms = group_syms(&m, g);
        for n in [5usize, 6, 7, 8] {
            let actual: HashMap<SymId, usize> = syms.iter().map(|&s| (s, n)).collect();
            cache.get_for(&m, g, &actual).unwrap();
        }
        assert_eq!(cache.stats.misses, 4, "static pipeline compiles per shape");
        assert_eq!(cache.stats.hits, 0);
    }

    #[test]
    fn same_pattern_shares_cache_across_modules() {
        // Two structurally identical modules share cache entries: the
        // signature is shape- and identity-agnostic.
        let m1 = chain();
        let m2 = chain();
        let p1 = plan(&m1, &FusionOptions::default());
        let p2 = plan(&m2, &FusionOptions::default());
        let dev = Arc::new(Device::cpu().unwrap());
        let mut cache = KernelCache::new(dev, BucketPolicy::NextPow2);
        let syms1 = group_syms(&m1, &p1.groups[0]);
        let actual1: HashMap<SymId, usize> = syms1.iter().map(|&s| (s, 7)).collect();
        cache.get_for(&m1, &p1.groups[0], &actual1).unwrap();
        let syms2 = group_syms(&m2, &p2.groups[0]);
        let actual2: HashMap<SymId, usize> = syms2.iter().map(|&s| (s, 8)).collect();
        cache.get_for(&m2, &p2.groups[0], &actual2).unwrap();
        assert_eq!(cache.stats.misses, 1);
        assert_eq!(cache.stats.hits, 1);
    }

    #[test]
    fn handles_share_the_store_compile_once() {
        // Two worker handles over one store: the second worker's first
        // touch of the pattern is a shared hit, not a compile.
        let m = chain();
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let dev = Arc::new(Device::cpu().unwrap());
        let store = Arc::new(KernelStore::new(dev));
        let mut w1 = KernelCache::with_store(store.clone(), BucketPolicy::NextPow2);
        let mut w2 = KernelCache::with_store(store.clone(), BucketPolicy::NextPow2);
        let syms = group_syms(&m, g);
        let actual: HashMap<SymId, usize> = syms.iter().map(|&s| (s, 6)).collect();
        w1.get_for(&m, g, &actual).unwrap();
        w2.get_for(&m, g, &actual).unwrap();
        assert_eq!(w1.stats.misses, 1);
        assert_eq!(w2.stats.misses, 0, "second worker must not recompile");
        assert_eq!(w2.stats.shared_hits, 1);
        let snap = store.snapshot();
        assert_eq!(snap.misses, 1, "one compile process-wide");
    }

    #[test]
    fn neighbor_prefetch_warms_next_bucket() {
        let m = chain();
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let dev = Arc::new(Device::cpu().unwrap());
        let mut cache = KernelCache::new(dev, BucketPolicy::NextPow2);
        let syms = group_syms(&m, g);
        let actual: HashMap<SymId, usize> = syms.iter().map(|&s| (s, 6)).collect();
        cache.get_for(&m, g, &actual).unwrap();
        let sig = signature(&m, g);
        cache.prefetch_neighbor(&m, g, &sig, &actual).unwrap();
        cache.store().quiesce();
        // Bucket 8 was demand-compiled; its pow2 neighbor 16 is now warm.
        let store_sig = format!("fused:{sig}");
        assert!(cache.store().is_ready(&store_sig, &[16]));
        // Traffic arriving at the neighbor stalls zero and compiles nothing.
        let misses = cache.stats.misses;
        let actual16: HashMap<SymId, usize> = syms.iter().map(|&s| (s, 13)).collect();
        cache.get_for(&m, g, &actual16).unwrap();
        assert_eq!(cache.stats.misses, misses, "warmed bucket must not compile");
        assert_eq!(cache.stats.shared_hits, 1);
    }

    #[test]
    fn neighbor_prefetch_consults_live_boundaries() {
        use crate::codegen::policy::{Boundaries, PolicySwitch};
        let m = chain();
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let dev = Arc::new(Device::cpu().unwrap());
        let mut cache = KernelCache::new(dev, BucketPolicy::NextPow2);
        let syms = group_syms(&m, g);
        let sw = Arc::new(PolicySwitch::new(BucketPolicy::NextPow2));
        cache.set_switch(sw.clone());
        let mut cuts = std::collections::BTreeMap::new();
        for &s in &syms {
            cuts.insert(s, vec![8, 12]);
        }
        sw.install(Boundaries { base: BucketPolicy::NextPow2, cuts });
        // Extent 6 buckets to the 8-cut; its neighbor under the live
        // boundaries is the 12-cut, NOT the pow2 16 the base would pick.
        let actual: HashMap<SymId, usize> = syms.iter().map(|&s| (s, 6)).collect();
        cache.get_for(&m, g, &actual).unwrap();
        let sig = signature(&m, g);
        cache.prefetch_neighbor(&m, g, &sig, &actual).unwrap();
        cache.store().quiesce();
        let store_sig = format!("fused:{sig}");
        assert!(
            cache.store().is_ready(&store_sig, &[12]),
            "neighbor warm must target the live policy's next cut"
        );
        assert!(
            !cache.store().is_ready(&store_sig, &[16]),
            "must not warm a bucket the live policy cannot produce"
        );
    }

    #[test]
    fn prefetch_bucketed_warms_candidate_family_before_install() {
        use crate::codegen::policy::Boundaries;
        let m = chain();
        let p = plan(&m, &FusionOptions::default());
        let g = &p.groups[0];
        let dev = Arc::new(Device::cpu().unwrap());
        let cache = KernelCache::new(dev, BucketPolicy::NextPow2);
        let syms = group_syms(&m, g);
        let mut cuts = std::collections::BTreeMap::new();
        for &s in &syms {
            cuts.insert(s, vec![40]);
        }
        let cand = Boundaries { base: BucketPolicy::NextPow2, cuts };
        let sig = signature(&m, g);
        cache.prefetch_bucketed(&m, g, &sig, &syms, &[33], &cand).unwrap();
        cache.store().quiesce();
        let store_sig = format!("fused:{sig}");
        assert!(
            cache.store().is_ready(&store_sig, &[40]),
            "candidate bucket must be compiled before the epoch flips"
        );
    }
}
