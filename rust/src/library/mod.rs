//! Static-shape kernel library for compute-intensive ops (§4.5).
//!
//! GEMM/Conv-class ops never go through fusion codegen: like the paper
//! (cuBLAS/cuDNN), they are served by a library that "chooses the best
//! kernel according to different runtime shapes". The library holds
//! PJRT-compiled dot executables keyed by exact `(b, m, k, n)` — the vendor
//! analogue: a library call is always available for any shape and its
//! compilation cost is *not* part of the dynamic-compiler overhead story
//! (frameworks ship the library pre-built; we count library compiles
//! separately in the stats). Pre-generated AOT artifacts (from
//! `python/compile/aot.py`) can be registered on top and win selection,
//! mirroring the paper's hand-tuned per-shape entries.
//!
//! The library is a first-class device-resident citizen (see
//! `docs/runtime.md`):
//!
//! * [`GemmLibrary::matmul_device`] accepts any mix of host tensors,
//!   device-resident buffers ([`GemmSrc::Dev`], chained straight from a
//!   fused kernel or an earlier GEMM), and cached weights, and leaves the
//!   result on device. Bucket adaptation of device operands happens *on
//!   device* through a compiled pad+mask "prepare" kernel — no host
//!   round-trip.
//! * A persistent **weight cache** ([`GemmLibrary::weight_device`]) keeps
//!   static RHS operands (graph constants, entry parameters) resident on
//!   device across calls, requests, and plan replays: each weight is
//!   padded and uploaded once per program, then served by reference.
//!   Installed launch plans *pin* the weights they reference; unpinned
//!   entries are evicted in LRU order whenever residency exceeds the
//!   store's byte budget ([`GemmLibrary::set_max_weight_bytes`]).
//!
//! Concurrency model (see docs/runtime.md §Concurrency): a `GemmLibrary`
//! is **per worker** — its entry/prep memo maps, buffer pool, and
//! [`LibraryStats`] are single-threaded hot-path state — but it backs onto
//! two **process-shared** stores: the [`crate::codegen::KernelStore`] (so
//! M workers build each GEMM/prepare executable exactly once) and the
//! [`WeightStore`] (so each weight uploads exactly once per program,
//! whichever worker touches it first, with pins accumulated across all
//! workers' plans).
//!
//! All host↔device payloads the library moves are accounted in
//! [`LibraryStats`] (`h2d_bytes`/`d2h_bytes`), which the executor folds
//! into `RunMetrics` — the bench tables and the metrics therefore agree on
//! library transfer traffic.

use crate::codegen::store::KernelStore;
use crate::codegen::BucketPolicy;
use crate::dhlo::{DType, ValueId};
use crate::runtime::buffers::BufferPool;
use crate::runtime::executor::{crop_box, pad_box};
use crate::runtime::pjrt::{Device, DeviceTensor, Executable};
use crate::runtime::tensor::{Data, Tensor};
use anyhow::{ensure, Result};
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// GEMM problem key: `[b?, m, k] · [b?, k, n]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmKey {
    pub batch: usize, // 0 = rank-2
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl GemmKey {
    /// Entry extents of the left operand.
    pub fn lhs_dims(&self) -> Vec<usize> {
        if self.batch == 0 {
            vec![self.m, self.k]
        } else {
            vec![self.batch, self.m, self.k]
        }
    }

    /// Entry extents of the right operand (the shape a cached weight is
    /// padded to).
    pub fn rhs_dims(&self) -> Vec<usize> {
        if self.batch == 0 {
            vec![self.k, self.n]
        } else {
            vec![self.batch, self.k, self.n]
        }
    }

    /// Entry extents of the result.
    pub fn out_dims(&self) -> Vec<usize> {
        if self.batch == 0 {
            vec![self.m, self.n]
        } else {
            vec![self.batch, self.m, self.n]
        }
    }
}

/// Identity of a cacheable weight: which program, which value slot. The
/// executor derives it from the generated flow; the library only needs it
/// to be stable across requests of the same compiled program.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct WeightKey {
    pub program: u64,
    pub value: ValueId,
}

/// One resident weight: the padded device buffer plus the validation
/// metadata that keeps Param-backed weights honest.
struct WeightEntry {
    dev: Arc<DeviceTensor>,
    /// Fingerprint of the *source* tensor (dims + raw bits); checked per
    /// call for Param weights, whose contents could change between
    /// requests even at a fixed shape.
    fingerprint: u64,
    /// Source (unpadded) dims, for a cheap shape-change reject.
    src_dims: Vec<usize>,
    /// Number of installed launch plans referencing this entry (summed
    /// across every worker's plan cache). Pinned entries are never evicted
    /// by the byte budget.
    pins: usize,
    bytes: u64,
}

/// The process-shared persistent weight cache. One mutex over the whole
/// table: weight traffic is one lookup per static GEMM operand per call —
/// orders of magnitude rarer than kernel-store lookups — and holding the
/// lock across the upload makes *upload-once* hold even when M workers
/// race the same cold weight.
pub struct WeightStore {
    inner: Mutex<WeightStoreInner>,
}

struct WeightStoreInner {
    weights: HashMap<WeightKey, WeightEntry>,
    /// Insertion/use order, for LRU eviction of unpinned entries.
    lru: VecDeque<WeightKey>,
    /// Byte budget for resident weights; pinned entries never count
    /// against evictability. Default effectively unbounded.
    max_bytes: u64,
    /// Per-program residency floors (multi-tenant arbitration): eviction
    /// never shrinks a program's resident weights below its floor, so one
    /// tenant's working set cannot flush another's past the guarantee.
    floors: HashMap<u64, u64>,
    evictions: u64,
}

impl Default for WeightStore {
    fn default() -> Self {
        Self::new()
    }
}

impl WeightStore {
    pub fn new() -> WeightStore {
        WeightStore {
            inner: Mutex::new(WeightStoreInner {
                weights: HashMap::new(),
                lru: VecDeque::new(),
                max_bytes: u64::MAX,
                floors: HashMap::new(),
                evictions: 0,
            }),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, WeightStoreInner> {
        // Process-shared, so poison recovery (see `util::relock`): a
        // panicking worker must not wedge every sibling's weight lookups.
        crate::util::relock(&self.inner)
    }

    /// Set the residency budget and enforce it immediately.
    pub fn set_max_bytes(&self, bytes: u64) {
        let mut inner = self.lock();
        inner.max_bytes = bytes;
        inner.enforce();
    }

    /// Guarantee `program` at least `bytes` of residency: eviction (from
    /// *any* tenant's traffic) will never shrink that program's resident
    /// weights below the floor. Floors are advisory capacity reservations —
    /// they don't pre-allocate, they only veto evictions — so the sum of
    /// floors should stay under `max_bytes` or the budget can overshoot.
    pub fn set_floor(&self, program: u64, bytes: u64) {
        self.lock().floors.insert(program, bytes);
    }

    /// Bytes of weights currently resident on device.
    pub fn resident_bytes(&self) -> u64 {
        self.lock().weights.values().map(|e| e.bytes).sum()
    }

    /// Bytes resident for one program (one tenant's model).
    pub fn resident_bytes_for(&self, program: u64) -> u64 {
        self.lock().resident_of(program)
    }

    /// Budget evictions performed so far.
    pub fn evictions(&self) -> u64 {
        self.lock().evictions
    }

    /// A launch plan referencing this weight was installed: protect the
    /// entry from budget eviction while the plan is cached. Returns
    /// whether a pin was actually taken — a missing entry (already
    /// budget-evicted) is fine, the next fetch re-uploads, but the caller
    /// must then *not* issue a matching unpin (it would steal a pin owned
    /// by another live plan).
    #[must_use]
    pub fn pin(&self, key: &WeightKey) -> bool {
        match self.lock().weights.get_mut(key) {
            Some(e) => {
                e.pins += 1;
                true
            }
            None => false,
        }
    }

    /// A plan cache dropped a plan referencing this weight; entries left
    /// unpinned become evictable when residency exceeds the budget.
    pub fn unpin(&self, key: &WeightKey) {
        let mut inner = self.lock();
        if let Some(e) = inner.weights.get_mut(key) {
            e.pins = e.pins.saturating_sub(1);
        }
        inner.enforce();
    }

    /// Fetch the resident copy of a weight, or insert it via `upload`.
    /// Returns `(buffer, hit)`. `validate` re-fingerprints the source
    /// (Param weights: same shape, possibly new contents); constants skip
    /// it. The upload runs under the store lock — see the type docs.
    pub fn get_or_upload<F>(
        &self,
        key: WeightKey,
        src: &Tensor,
        pad_dims: &[usize],
        validate: bool,
        upload: F,
    ) -> Result<(Arc<DeviceTensor>, bool)>
    where
        F: FnOnce() -> Result<DeviceTensor>,
    {
        let fp = if validate { Some(fingerprint(src)) } else { None };
        let mut inner = self.lock();
        if let Some(e) = inner.weights.get(&key) {
            if e.dev.dims == pad_dims
                && e.src_dims == src.dims
                && fp.unwrap_or(e.fingerprint) == e.fingerprint
            {
                let dev = e.dev.clone();
                // Refresh recency so the budget evicts cold entries first.
                inner.lru.retain(|k| k != &key);
                inner.lru.push_back(key);
                return Ok((dev, true));
            }
        }
        let dev = Arc::new(upload()?);
        let bytes = dev.byte_size() as u64;
        let fp = fp.unwrap_or_else(|| fingerprint(src));
        let pins = inner.weights.remove(&key).map(|e| e.pins).unwrap_or(0);
        inner.weights.insert(
            key.clone(),
            WeightEntry {
                dev: dev.clone(),
                fingerprint: fp,
                src_dims: src.dims.clone(),
                pins,
                bytes,
            },
        );
        inner.lru.retain(|k| k != &key);
        inner.lru.push_back(key);
        inner.enforce();
        Ok((dev, false))
    }
}

impl WeightStoreInner {
    fn resident(&self) -> u64 {
        self.weights.values().map(|e| e.bytes).sum()
    }

    fn resident_of(&self, program: u64) -> u64 {
        self.weights
            .iter()
            .filter(|(k, _)| k.program == program)
            .map(|(_, e)| e.bytes)
            .sum()
    }

    /// Evict cold unpinned entries (LRU order) until the budget holds.
    /// An entry is exempt while evicting it would drop its program below
    /// that program's floor; if only pinned or floor-protected entries
    /// remain, the budget is allowed to overshoot rather than starve a
    /// tenant's guaranteed working set.
    fn enforce(&mut self) {
        while self.resident() > self.max_bytes {
            let evictable = self.lru.iter().position(|k| {
                let Some(e) = self.weights.get(k) else { return true };
                let floor = self.floors.get(&k.program).copied().unwrap_or(0);
                e.pins == 0 && self.resident_of(k.program) - e.bytes >= floor
            });
            let Some(pos) = evictable else { break };
            let k = self.lru.remove(pos).unwrap();
            if self.weights.remove(&k).is_some() {
                self.evictions += 1;
            }
        }
    }
}

/// FNV-1a style fingerprint over dims + raw element bits.
fn fingerprint(t: &Tensor) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |b: u64| {
        h ^= b;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    eat(t.dims.len() as u64);
    for &d in &t.dims {
        eat(d as u64);
    }
    match &t.data {
        Data::F32(v) => v.iter().for_each(|x| eat(x.to_bits() as u64)),
        Data::I64(v) => v.iter().for_each(|&x| eat(x as u64)),
        Data::I32(v) => v.iter().for_each(|&x| eat(x as u32 as u64)),
        Data::Pred(v) => v.iter().for_each(|&x| eat(x as u64)),
    }
    h
}

#[derive(Debug, Clone, Default)]
pub struct LibraryStats {
    pub calls: u64,
    pub entries_built: u64,
    /// Device-side bucket-adapter ("prepare") kernels compiled.
    pub prep_built: u64,
    pub build_time: Duration,
    /// Time this handle spent blocked on the shared compile service for
    /// GEMM/prepare builds (own compiles and single-flight joins alike) —
    /// folded into `RunMetrics::compile_stall` next to the fused-kernel
    /// stall.
    pub build_stall: Duration,
    /// GEMM/prepare fetches that joined another worker's in-flight compile.
    pub build_dedup_hits: u64,
    pub exec_time: Duration,
    pub flops: u64,
    pub pregen_hits: u64,
    /// Host↔device payload the library moved (uploads of operands and
    /// weights, readbacks of results — including the implicit marshalling
    /// of the host execution path, which transfers every operand in and
    /// the result out on real PJRT).
    pub h2d_bytes: u64,
    pub d2h_bytes: u64,
    /// Weight-cache behavior observed by *this* handle: a hit serves the
    /// device-resident buffer by reference (zero transfer); a miss pads +
    /// uploads. Evictions are a store-level count
    /// ([`GemmLibrary::weight_evictions`]).
    pub weight_hits: u64,
    pub weight_misses: u64,
}

/// The kernel library (one per executor worker; shared stores behind it —
/// see the module docs).
pub struct GemmLibrary {
    device: Arc<Device>,
    /// Local memo of store-fetched GEMM executables (lock-free hot path).
    entries: HashMap<GemmKey, Arc<Executable>>,
    /// Pre-generated (AOT) entries registered from artifacts; these take
    /// priority over on-demand built ones, like the paper's hand-tuned set.
    pregen: HashMap<GemmKey, Arc<Executable>>,
    /// Vendor libraries serve *any* shape from a fixed kernel set; we model
    /// that by bucketing the dynamic `m`/batch row dimension (k and n come
    /// from static weights). Without this, a dynamic workload would force
    /// one build per sequence length — exactly the pathology cuBLAS does
    /// not have.
    pub m_bucket: BucketPolicy,
    /// Pool for padded-operand scratch (the cached allocator of §4.2.2).
    pool: BufferPool,
    /// Process-shared compiled-kernel store backing GEMM entry and
    /// prepare-kernel builds (compile-once across workers).
    store: Arc<KernelStore>,
    /// Process-shared persistent device-resident weights (see module docs).
    weights: Arc<WeightStore>,
    /// Local memo of device-side bucket adapters: mask actual lanes +
    /// pad/crop to the entry extents, keyed by `(src_dims, dst_dims)`.
    prep: HashMap<(Vec<usize>, Vec<usize>), Arc<Executable>>,
    /// Pre-uploaded s32 extent scalars fed to prepare kernels (uploaded
    /// once per distinct extent value, ~4 bytes each).
    scalars: HashMap<i32, Arc<DeviceTensor>>,
    pub stats: LibraryStats,
}

/// One GEMM operand, wherever it currently lives.
pub enum GemmSrc<'a> {
    /// Host tensor at actual extents: padded host-side if needed, then
    /// uploaded (the classic path).
    Host(&'a Tensor),
    /// Device-resident buffer at bucket extents with `actual` valid lanes.
    /// `zero_padded` asserts the pad lanes are exact zeros (true for GEMM
    /// results, false for fused-kernel outputs, whose pad lanes are
    /// garbage); non-zero-padded or bucket-mismatched operands are adapted
    /// on device by a prepare kernel.
    Dev { dt: &'a DeviceTensor, actual: &'a [usize], zero_padded: bool },
    /// A cached weight, already padded to the entry extents and exactly
    /// zero-padded (from [`GemmLibrary::weight_device`]).
    Weight { dt: Arc<DeviceTensor>, actual: &'a [usize] },
}

impl GemmSrc<'_> {
    fn actual_dims(&self) -> &[usize] {
        match self {
            GemmSrc::Host(t) => &t.dims,
            GemmSrc::Dev { actual, .. } => actual,
            GemmSrc::Weight { actual, .. } => actual,
        }
    }

    /// Bytes of the operand at its actual extents (f32 device payloads;
    /// used for the executor's `lib_bytes` modeling).
    pub fn actual_byte_size(&self) -> u64 {
        match self {
            GemmSrc::Host(t) => t.byte_size() as u64,
            GemmSrc::Dev { actual, .. } | GemmSrc::Weight { actual, .. } => {
                (actual.iter().product::<usize>() * 4) as u64
            }
        }
    }
}

/// A marshalled device operand: borrowed when it can be consumed in place,
/// owned/shared when marshalling produced a fresh buffer.
enum Marshalled<'a> {
    Owned(DeviceTensor),
    Shared(Arc<DeviceTensor>),
    Borrowed(&'a DeviceTensor),
}

impl Marshalled<'_> {
    fn get(&self) -> &DeviceTensor {
        match self {
            Marshalled::Owned(d) => d,
            Marshalled::Shared(d) => d,
            Marshalled::Borrowed(d) => d,
        }
    }
}

impl GemmLibrary {
    /// Standalone library over private stores (single-worker uses, the
    /// eager/VM baselines, tests).
    pub fn new(device: Arc<Device>) -> Self {
        let store = Arc::new(KernelStore::new(device.clone()));
        Self::with_shared(device, store, Arc::new(WeightStore::new()))
    }

    /// A per-worker library handle over process-shared kernel and weight
    /// stores.
    pub fn with_shared(
        device: Arc<Device>,
        store: Arc<KernelStore>,
        weights: Arc<WeightStore>,
    ) -> Self {
        GemmLibrary {
            device,
            entries: HashMap::new(),
            pregen: HashMap::new(),
            m_bucket: BucketPolicy::MultipleOf(16),
            pool: BufferPool::new(),
            store,
            weights,
            prep: HashMap::new(),
            scalars: HashMap::new(),
            stats: LibraryStats::default(),
        }
    }

    /// The shared weight store behind this handle.
    pub fn weight_store(&self) -> &Arc<WeightStore> {
        &self.weights
    }

    /// Register a pre-generated executable (from an AOT artifact) for a
    /// specific problem shape.
    pub fn register_pregen(&mut self, key: GemmKey, exe: Executable) {
        self.pregen.insert(key, Arc::new(exe));
    }

    pub fn has_pregen(&self, key: &GemmKey) -> bool {
        self.pregen.contains_key(key)
    }

    fn dot_hlo(key: &GemmKey) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        if key.batch == 0 {
            let (m, k, n) = (key.m, key.k, key.n);
            let _ = write!(
                s,
                "HloModule gemm, entry_computation_layout={{(f32[{m},{k}]{{1,0}}, f32[{k},{n}]{{1,0}})->f32[{m},{n}]{{1,0}}}}\n\n\
                 ENTRY main {{\n  \
                 a = f32[{m},{k}]{{1,0}} parameter(0)\n  \
                 b = f32[{k},{n}]{{1,0}} parameter(1)\n  \
                 ROOT d = f32[{m},{n}]{{1,0}} dot(a, b), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
                 }}\n"
            );
        } else {
            let (bs, m, k, n) = (key.batch, key.m, key.k, key.n);
            let _ = write!(
                s,
                "HloModule bgemm, entry_computation_layout={{(f32[{bs},{m},{k}]{{2,1,0}}, f32[{bs},{k},{n}]{{2,1,0}})->f32[{bs},{m},{n}]{{2,1,0}}}}\n\n\
                 ENTRY main {{\n  \
                 a = f32[{bs},{m},{k}]{{2,1,0}} parameter(0)\n  \
                 b = f32[{bs},{k},{n}]{{2,1,0}} parameter(1)\n  \
                 ROOT d = f32[{bs},{m},{n}]{{2,1,0}} dot(a, b), lhs_batch_dims={{0}}, rhs_batch_dims={{0}}, lhs_contracting_dims={{2}}, rhs_contracting_dims={{1}}\n\
                 }}\n"
            );
        }
        s
    }

    fn entry_for(&mut self, key: GemmKey) -> Result<Arc<Executable>> {
        if let Some(e) = self.pregen.get(&key) {
            self.stats.pregen_hits += 1;
            return Ok(e.clone());
        }
        if let Some(e) = self.entries.get(&key) {
            return Ok(e.clone());
        }
        // Miss in the local memo: fetch through the shared store so M
        // workers build each entry once. Build accounting stays on the
        // handle that actually compiled (RunMetrics attribution).
        let name = format!("gemm_{}x{}x{}x{}", key.batch, key.m, key.k, key.n);
        let (e, fetch) = self
            .store
            .get_or_compile("lib:gemm", &[key.batch, key.m, key.k, key.n], move || {
                Ok((name, Self::dot_hlo(&key)))
            })?;
        if fetch.compiled {
            self.stats.entries_built += 1;
            self.stats.build_time += e.compile_time;
        } else if fetch.deduped {
            self.stats.build_dedup_hits += 1;
        }
        self.stats.build_stall += fetch.stall;
        self.entries.insert(key, e.clone());
        Ok(e)
    }

    /// The concrete `(m, k, n)` problem plus batch count of `a · b`, from
    /// actual operand dims.
    fn problem_of_dims(a: &[usize], b: &[usize]) -> Result<((usize, usize, usize), usize)> {
        match (a.len(), b.len()) {
            (2, 2) => {
                ensure!(a[1] == b[0], "gemm: contracting mismatch");
                Ok(((a[0], a[1], b[1]), 0usize))
            }
            (3, 3) => {
                ensure!(a[0] == b[0] && a[2] == b[1], "bgemm mismatch");
                Ok(((a[1], a[2], b[2]), a[0]))
            }
            (ra, rb) => anyhow::bail!("library matmul: ranks {ra}x{rb}"),
        }
    }

    fn problem_of(a: &Tensor, b: &Tensor) -> Result<((usize, usize, usize), usize)> {
        Self::problem_of_dims(&a.dims, &b.dims)
    }

    /// Resolve the library entry key for a problem: exact pre-generated
    /// entries win over bucketing (the hand-tuned set, §4.5). Launch plans
    /// record this key so replays skip the derivation entirely.
    pub fn key_for(&self, a: &Tensor, b: &Tensor) -> Result<GemmKey> {
        let ((m, k, n), batch) = Self::problem_of(a, b)?;
        let exact_key = GemmKey { batch, m, k, n };
        Ok(if self.pregen.contains_key(&exact_key) {
            exact_key
        } else {
            GemmKey {
                batch,
                m: self.m_bucket.bucket(m),
                k: self.m_bucket.bucket(k),
                n: self.m_bucket.bucket(n),
            }
        })
    }

    /// Execute `a · b` through the library. Every dynamic problem dim is
    /// bucketed (vendor-library style: a fixed kernel set serves any
    /// shape): padded `m` rows and `n` columns are cropped from the result,
    /// and a zero-padded contracting `k` is mathematically exact (the extra
    /// products are zero).
    pub fn matmul(&mut self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        let key = self.key_for(a, b)?;
        self.matmul_with_key(a, b, key)
    }

    /// Pad both operands up to the entry's bucket extents (pool-backed
    /// scratch; `None` = aligned, passed by reference) and compute the
    /// bucket-shaped output dims. Shared by the host and device execution
    /// paths so their marshalling can never diverge.
    fn pad_for_entry(
        pool: &mut BufferPool,
        a: &Tensor,
        b: &Tensor,
        key: GemmKey,
        batch: usize,
    ) -> Result<(Option<Tensor>, Option<Tensor>, Vec<usize>)> {
        let mut pad2 = |t: &Tensor, d0: usize, d1: usize| -> Result<Option<Tensor>> {
            if t.rank() == 2 {
                if t.dims == [d0, d1] {
                    Ok(None)
                } else {
                    pad_box(t, &[d0, d1], Some(pool)).map(Some)
                }
            } else if t.dims[1] == d0 && t.dims[2] == d1 {
                Ok(None)
            } else {
                pad_box(t, &[batch, d0, d1], Some(pool)).map(Some)
            }
        };
        let a_pad = pad2(a, key.m, key.k)?;
        let b_pad = pad2(b, key.k, key.n)?;
        let out_dims = if batch == 0 {
            vec![key.m, key.n]
        } else {
            vec![batch, key.m, key.n]
        };
        Ok((a_pad, b_pad, out_dims))
    }

    /// Return pooled pad scratch and bump the per-call stats.
    fn finish_call(&mut self, pads: [Option<Tensor>; 2], batch: usize, flops_mkn: usize) {
        for t in pads.into_iter().flatten() {
            if let Data::F32(v) = t.data {
                if v.capacity() > 0 {
                    self.pool.free_f32(v);
                }
            }
        }
        self.stats.calls += 1;
        self.stats.flops += (2 * batch.max(1) * flops_mkn) as u64;
    }

    /// Execute with a pre-resolved entry key (the launch-plan replay path:
    /// no shape derivation, no pregen probe, no bucket math). Host in, host
    /// out; the implicit operand/result marshalling is accounted as
    /// transfer traffic (it is, on real PJRT).
    pub fn matmul_with_key(&mut self, a: &Tensor, b: &Tensor, key: GemmKey) -> Result<Tensor> {
        let ((m, k, n), batch) = Self::problem_of(a, b)?;
        let exe = self.entry_for(key)?;
        let t_call = std::time::Instant::now();
        let (a_pad, b_pad, out_dims) = Self::pad_for_entry(&mut self.pool, a, b, key, batch)?;
        let args = [a_pad.as_ref().unwrap_or(a), b_pad.as_ref().unwrap_or(b)];
        for t in &args {
            self.stats.h2d_bytes += t.byte_size() as u64;
        }
        let out = exe.run(&args, &out_dims, DType::F32)?;
        self.stats.d2h_bytes += out.byte_size() as u64;
        self.finish_call([a_pad, b_pad], batch, m * k * n);
        let result = if (key.m, key.n) == (m, n) {
            Ok(out)
        } else if batch == 0 {
            crop_box(&out, &[m, n])
        } else {
            crop_box(&out, &[batch, m, n])
        };
        self.stats.exec_time += t_call.elapsed();
        result
    }

    /// Execute with a pre-resolved key over operands wherever they live,
    /// leaving the (bucket-shaped) result on device. Returns the device
    /// tensor plus the *actual* output dims.
    ///
    /// The pad region of the result is exact zeros (all marshalled
    /// operands are zero-padded: host pads, prepare-kernel outputs, and
    /// cached weights alike), so downstream consumers may read the buffer
    /// directly when their bucket shape matches — including other GEMMs
    /// contracting over the padded axis.
    pub fn matmul_device(
        &mut self,
        a: GemmSrc<'_>,
        b: GemmSrc<'_>,
        key: GemmKey,
    ) -> Result<(DeviceTensor, Vec<usize>)> {
        let ((m, k, n), batch) = Self::problem_of_dims(a.actual_dims(), b.actual_dims())?;
        let exe = self.entry_for(key)?;
        let t_call = std::time::Instant::now();
        let build0 = self.stats.build_time;
        let da = self.marshal(a, &key.lhs_dims())?;
        let db = self.marshal(b, &key.rhs_dims())?;
        let out = exe.run_on_device(&[da.get(), db.get()], &key.out_dims(), DType::F32)?;
        drop((da, db));
        self.stats.calls += 1;
        self.stats.flops += (2 * batch.max(1) * m * k * n) as u64;
        // Marshalling may compile a prepare kernel; that is one-time build
        // cost (already in build_time), not execution time.
        self.stats.exec_time +=
            t_call.elapsed().saturating_sub(self.stats.build_time - build0);
        let actual = if batch == 0 { vec![m, n] } else { vec![batch, m, n] };
        Ok((out, actual))
    }

    /// Pad a host tensor to `want` (pool-backed scratch) and upload it,
    /// with the transfer accounted. The single implementation behind both
    /// host-operand marshalling and weight uploads.
    fn pad_upload(&mut self, t: &Tensor, want: &[usize]) -> Result<DeviceTensor> {
        ensure!(t.rank() == want.len(), "gemm operand rank mismatch");
        let padded =
            if t.dims == want { None } else { Some(pad_box(t, want, Some(&mut self.pool))?) };
        let up = padded.as_ref().unwrap_or(t);
        let dt = self.device.h2d(up)?;
        self.stats.h2d_bytes += up.byte_size() as u64;
        if let Some(p) = padded {
            if let Data::F32(v) = p.data {
                if v.capacity() > 0 {
                    self.pool.free_f32(v);
                }
            }
        }
        Ok(dt)
    }

    /// Bring one operand to the entry extents on device.
    fn marshal<'a>(&mut self, src: GemmSrc<'a>, want: &[usize]) -> Result<Marshalled<'a>> {
        match src {
            GemmSrc::Host(t) => self.pad_upload(t, want).map(Marshalled::Owned),
            GemmSrc::Dev { dt, actual, zero_padded } => {
                if dt.dims == want && zero_padded {
                    Ok(Marshalled::Borrowed(dt))
                } else {
                    self.prepare_on_device(dt, actual, want).map(Marshalled::Owned)
                }
            }
            GemmSrc::Weight { dt, .. } => {
                ensure!(dt.dims == want, "cached weight extents diverged from entry");
                Ok(Marshalled::Shared(dt))
            }
        }
    }

    /// Device-side bucket adaptation: zero every lane outside the `actual`
    /// box (fused-kernel pad lanes are garbage) and grow/shrink to the
    /// entry extents — one compiled kernel per `(src, dst)` bucket pair,
    /// extent scalars passed as pre-uploaded device buffers. No host
    /// round-trip, no payload transfer.
    fn prepare_on_device(
        &mut self,
        dt: &DeviceTensor,
        actual: &[usize],
        want: &[usize],
    ) -> Result<DeviceTensor> {
        ensure!(
            dt.dims.len() == want.len() && actual.len() == want.len(),
            "gemm prepare rank mismatch"
        );
        let exe = self.prep_entry(&dt.dims, want)?;
        let mut scalars: Vec<Arc<DeviceTensor>> = Vec::with_capacity(actual.len());
        for &e in actual {
            scalars.push(self.scalar_i32(e as i32)?);
        }
        let mut args: Vec<&DeviceTensor> = Vec::with_capacity(1 + scalars.len());
        args.push(dt);
        for s in &scalars {
            args.push(s);
        }
        exe.run_on_device(&args, want, DType::F32)
    }

    /// HLO for a prepare kernel: `pad` to the destination bucket, then mask
    /// lanes `>= actual` to zero via iota/compare/select.
    fn prep_hlo(src: &[usize], dst: &[usize]) -> String {
        use std::fmt::Write as _;
        let rank = src.len();
        let dims = |d: &[usize]| {
            d.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",")
        };
        let layout = (0..rank).rev().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
        let sty = format!("f32[{}]{{{layout}}}", dims(src));
        let dty = format!("f32[{}]{{{layout}}}", dims(dst));
        let ity = format!("s32[{}]{{{layout}}}", dims(dst));
        let pty = format!("pred[{}]{{{layout}}}", dims(dst));
        let mut s = String::new();
        let scalar_params =
            (0..rank).map(|_| "s32[]".to_string()).collect::<Vec<_>>().join(", ");
        let _ = write!(
            s,
            "HloModule gemm_prep, entry_computation_layout={{({sty}, {scalar_params})->{dty}}}\n\n\
             ENTRY main {{\n  x = {sty} parameter(0)\n"
        );
        for ax in 0..rank {
            let _ = write!(s, "  e{ax} = s32[] parameter({})\n", ax + 1);
        }
        let _ = write!(s, "  zero = f32[] constant(0)\n");
        let source = if src == dst {
            "x".to_string()
        } else {
            let padding = (0..rank)
                .map(|ax| format!("0_{}", dst[ax] as i64 - src[ax] as i64))
                .collect::<Vec<_>>()
                .join("x");
            let _ = write!(s, "  xp = {dty} pad(x, zero), padding={padding}\n");
            "xp".to_string()
        };
        for ax in 0..rank {
            let _ = write!(s, "  i{ax} = {ity} iota(), iota_dimension={ax}\n");
            let _ = write!(s, "  b{ax} = {ity} broadcast(e{ax}), dimensions={{}}\n");
            let _ = write!(s, "  m{ax} = {pty} compare(i{ax}, b{ax}), direction=LT\n");
        }
        let mut mask = "m0".to_string();
        for ax in 1..rank {
            let next = format!("ma{ax}");
            let _ = write!(s, "  {next} = {pty} and({mask}, m{ax})\n");
            mask = next;
        }
        let _ = write!(s, "  zb = {dty} broadcast(zero), dimensions={{}}\n");
        let _ = write!(s, "  ROOT out = {dty} select({mask}, {source}, zb)\n}}\n");
        s
    }

    fn prep_entry(&mut self, src: &[usize], dst: &[usize]) -> Result<Arc<Executable>> {
        let key = (src.to_vec(), dst.to_vec());
        if let Some(e) = self.prep.get(&key) {
            return Ok(e.clone());
        }
        // Store key: src extents ++ dst extents (equal ranks, so the split
        // point is implied by the length).
        let store_dims: Vec<usize> = src.iter().chain(dst.iter()).copied().collect();
        let name = format!(
            "gemm_prep_{}_to_{}",
            src.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x"),
            dst.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x")
        );
        let (e, fetch) = self
            .store
            .get_or_compile("lib:prep", &store_dims, || Ok((name, Self::prep_hlo(src, dst))))?;
        if fetch.compiled {
            self.stats.prep_built += 1;
            self.stats.build_time += e.compile_time;
        } else if fetch.deduped {
            self.stats.build_dedup_hits += 1;
        }
        self.stats.build_stall += fetch.stall;
        self.prep.insert(key, e.clone());
        Ok(e)
    }

    fn scalar_i32(&mut self, v: i32) -> Result<Arc<DeviceTensor>> {
        if let Some(s) = self.scalars.get(&v) {
            return Ok(s.clone());
        }
        let t = Tensor::i32(&[], vec![v]);
        let dt = Arc::new(self.device.h2d(&t)?);
        self.stats.h2d_bytes += t.byte_size() as u64;
        self.scalars.insert(v, dt.clone());
        Ok(dt)
    }

    /// Read a device-resident library result back to the host, cropped to
    /// its actual extents (transfer accounted here, not at the caller).
    pub fn readback(&mut self, dt: &DeviceTensor, actual: &[usize]) -> Result<Tensor> {
        let full = self.device.d2h(dt)?;
        self.stats.d2h_bytes += full.byte_size() as u64;
        if full.dims == actual {
            Ok(full)
        } else {
            crop_box(&full, actual)
        }
    }

    // --- persistent weight cache ---------------------------------------

    /// Fetch (or upload) the device-resident copy of a weight, padded to
    /// `pad_dims`. `validate` re-fingerprints the source per call (Param
    /// weights: same shape, possibly new contents); constants skip it.
    ///
    /// The Param tradeoff is deliberate: serving weights are routinely
    /// passed as parameters with stable contents, so the per-call O(bytes)
    /// host hash replaces a per-call O(bytes) *transfer*. A Param RHS that
    /// genuinely changes every request (an activation·activation dot)
    /// degrades to hash+upload per call — no worse than the upload-only
    /// path it replaced — and its single stale entry stays bounded by the
    /// pin/budget machinery like any other.
    pub fn weight_device(
        &mut self,
        key: WeightKey,
        src: &Tensor,
        pad_dims: &[usize],
        validate: bool,
    ) -> Result<Arc<DeviceTensor>> {
        let store = self.weights.clone();
        let (dev, hit) =
            store.get_or_upload(key, src, pad_dims, validate, || self.pad_upload(src, pad_dims))?;
        if hit {
            self.stats.weight_hits += 1;
        } else {
            self.stats.weight_misses += 1;
        }
        Ok(dev)
    }

    /// Pin a weight on behalf of an installed launch plan (forwards to the
    /// shared [`WeightStore`]; see [`WeightStore::pin`] for the contract).
    #[must_use]
    pub fn pin_weight(&mut self, key: &WeightKey) -> bool {
        self.weights.pin(key)
    }

    /// Release one plan's pin (forwards to the shared store).
    pub fn unpin_weight(&mut self, key: &WeightKey) {
        self.weights.unpin(key)
    }

    /// Bytes of weights currently resident on device (process-wide gauge).
    pub fn weight_resident_bytes(&self) -> u64 {
        self.weights.resident_bytes()
    }

    /// Budget evictions performed by the shared weight store.
    pub fn weight_evictions(&self) -> u64 {
        self.weights.evictions()
    }

    /// Set the process-wide weight residency budget (and enforce it).
    pub fn set_max_weight_bytes(&mut self, bytes: u64) {
        self.weights.set_max_bytes(bytes);
    }

    /// Reserve a per-program residency floor in the shared store (see
    /// [`WeightStore::set_floor`]) — the multi-tenant arbitration knob.
    pub fn set_weight_floor(&mut self, program: u64, bytes: u64) {
        self.weights.set_floor(program, bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_matches_reference() {
        let dev = Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev);
        let a = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::f32(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let out = lib.matmul(&a, &b).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[58., 64., 139., 154.]);
        assert_eq!(lib.stats.calls, 1);
        assert_eq!(lib.stats.flops, 2 * 2 * 3 * 2);
        assert!(lib.stats.h2d_bytes > 0, "host path transfers are accounted");
        assert!(lib.stats.d2h_bytes > 0);
    }

    #[test]
    fn batched_gemm() {
        let dev = Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev);
        let a = Tensor::f32(&[2, 1, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::f32(&[2, 2, 1], vec![1., 1., 2., 2.]);
        let out = lib.matmul(&a, &b).unwrap();
        assert_eq!(out.dims, vec![2, 1, 1]);
        assert_eq!(out.as_f32().unwrap(), &[3., 14.]);
    }

    #[test]
    fn entries_are_reused() {
        let dev = Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev);
        let a = Tensor::f32(&[2, 2], vec![1.; 4]);
        let b = Tensor::f32(&[2, 2], vec![1.; 4]);
        lib.matmul(&a, &b).unwrap();
        lib.matmul(&a, &b).unwrap();
        assert_eq!(lib.stats.entries_built, 1);
        assert_eq!(lib.stats.calls, 2);
    }

    #[test]
    fn device_path_with_cached_weight_bit_matches_host_path() {
        let dev = Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev.clone());
        let a = Tensor::f32(&[3, 5], (0..15).map(|i| 0.1 * i as f32).collect());
        let w = Tensor::f32(&[5, 4], (0..20).map(|i| 0.05 * i as f32 - 0.3).collect());
        let key = lib.key_for(&a, &w).unwrap();
        let host = lib.matmul_with_key(&a, &w, key).unwrap();

        let wk = WeightKey { program: 1, value: 7 };
        let wdev = lib.weight_device(wk.clone(), &w, &key.rhs_dims(), false).unwrap();
        let (out, actual) = lib
            .matmul_device(
                GemmSrc::Host(&a),
                GemmSrc::Weight { dt: wdev, actual: &w.dims },
                key,
            )
            .unwrap();
        let back = lib.readback(&out, &actual).unwrap();
        assert_eq!(back, host, "device path must be bit-exact vs host path");
    }

    #[test]
    fn weights_upload_once_and_validate_on_content_change() {
        let dev = Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev);
        let w = Tensor::f32(&[4, 4], vec![0.5; 16]);
        let wk = WeightKey { program: 9, value: 3 };
        let pad = vec![16usize, 16];
        let h2d0 = lib.stats.h2d_bytes;
        lib.weight_device(wk.clone(), &w, &pad, true).unwrap();
        assert_eq!(lib.stats.weight_misses, 1);
        let h2d_after_first = lib.stats.h2d_bytes;
        assert!(h2d_after_first > h2d0);
        // Same contents: served by reference, zero transfer.
        lib.weight_device(wk.clone(), &w, &pad, true).unwrap();
        lib.weight_device(wk.clone(), &w, &pad, true).unwrap();
        assert_eq!(lib.stats.weight_hits, 2);
        assert_eq!(lib.stats.h2d_bytes, h2d_after_first);
        // Changed contents at the same shape: fingerprint rejects, re-upload.
        let w2 = Tensor::f32(&[4, 4], vec![0.25; 16]);
        lib.weight_device(wk, &w2, &pad, true).unwrap();
        assert_eq!(lib.stats.weight_misses, 2);
        assert!(lib.stats.h2d_bytes > h2d_after_first);
    }

    #[test]
    fn weight_budget_evicts_unpinned_lru_only() {
        let dev = Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev);
        let w = Tensor::f32(&[2, 2], vec![1.; 4]);
        let ka = WeightKey { program: 1, value: 1 };
        let kb = WeightKey { program: 1, value: 2 };
        lib.weight_device(ka.clone(), &w, &[2, 2], false).unwrap();
        assert!(lib.pin_weight(&ka), "resident entry must accept the pin");
        assert_eq!(lib.weight_resident_bytes(), 16);
        // Tighten the budget to zero: ka is pinned and must survive every
        // later enforcement point.
        lib.set_max_weight_bytes(0);
        lib.weight_device(kb.clone(), &w, &[2, 2], false).unwrap();
        // kb is unpinned and over budget: evicted at insert; ka stays.
        assert_eq!(lib.weight_evictions(), 1);
        assert_eq!(lib.weight_resident_bytes(), 16);
        // Unpinning ka makes it evictable.
        lib.unpin_weight(&ka);
        assert_eq!(lib.weight_resident_bytes(), 0);
        assert_eq!(lib.weight_evictions(), 2);
        // A pin attempt on an evicted entry takes no pin (the caller must
        // not later issue a matching unpin).
        assert!(!lib.pin_weight(&kb));
    }

    #[test]
    fn weight_floor_protects_a_tenant_from_cross_program_eviction() {
        let dev = Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev);
        let w = Tensor::f32(&[2, 2], vec![1.; 4]); // 16 bytes resident each
        let a1 = WeightKey { program: 1, value: 1 };
        let a2 = WeightKey { program: 1, value: 2 };
        let b1 = WeightKey { program: 2, value: 1 };
        // Program 1 is guaranteed one entry's worth of residency.
        lib.set_weight_floor(1, 16);
        lib.weight_device(a1.clone(), &w, &[2, 2], false).unwrap();
        lib.weight_device(a2.clone(), &w, &[2, 2], false).unwrap();
        assert_eq!(lib.weight_store().resident_bytes_for(1), 32);
        // Budget of one entry: program 2's upload must evict program 1's
        // cold surplus (a1) and then stop — a2 is floor-protected even
        // though it is unpinned and the budget is still exceeded.
        lib.set_max_weight_bytes(16);
        assert_eq!(lib.weight_evictions(), 1, "surplus above the floor goes");
        lib.weight_device(b1, &w, &[2, 2], false).unwrap();
        assert_eq!(
            lib.weight_store().resident_bytes_for(1),
            16,
            "program 1 holds exactly its floor"
        );
        assert!(
            lib.weight_store().resident_bytes_for(2) > 0 || lib.weight_evictions() >= 2,
            "program 2 either stays resident (overshoot) or was evicted itself"
        );
        // Program 1's own traffic above its floor is still evictable: a
        // re-upload of a1 makes a2 the cold surplus entry.
        let evictions_before = lib.weight_evictions();
        lib.weight_device(a1, &w, &[2, 2], false).unwrap();
        assert!(lib.weight_evictions() > evictions_before);
        assert_eq!(
            lib.weight_store().resident_bytes_for(1),
            16,
            "floor holds, but identity of the survivor follows LRU"
        );
    }

    #[test]
    fn weight_hits_refresh_lru_recency() {
        let dev = Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev);
        let w = Tensor::f32(&[2, 2], vec![1.; 4]);
        let ka = WeightKey { program: 1, value: 1 };
        let kb = WeightKey { program: 1, value: 2 };
        lib.weight_device(ka.clone(), &w, &[2, 2], false).unwrap();
        lib.weight_device(kb.clone(), &w, &[2, 2], false).unwrap();
        // Hit ka: it becomes the most recently used entry.
        lib.weight_device(ka.clone(), &w, &[2, 2], false).unwrap();
        // Budget holds one entry; the next enforcement point must evict
        // the cold kb, not the hot ka.
        lib.set_max_weight_bytes(16);
        lib.unpin_weight(&kb); // no pin held — just an enforcement point
        assert_eq!(lib.weight_resident_bytes(), 16);
        let misses = lib.stats.weight_misses;
        lib.weight_device(ka, &w, &[2, 2], false).unwrap();
        assert_eq!(lib.stats.weight_misses, misses, "hot entry survived");
    }

    #[test]
    fn prepare_kernel_masks_garbage_and_adapts_buckets() {
        // A "fused kernel output": bucket [4,4] whose valid box is [2,3],
        // pad lanes filled with garbage. Chained into a GEMM entry that
        // wants [16,16] operands, the prepare kernel must zero the garbage
        // and grow the bucket on device — bit-identical to the host path
        // (crop + re-pad) over the same values.
        let dev = Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev.clone());
        let mut buf = vec![999.0f32; 16];
        let valid = [1.0f32, 2., 3., 4., 5., 6.];
        for r in 0..2 {
            for c in 0..3 {
                buf[r * 4 + c] = valid[r * 3 + c];
            }
        }
        let bucketed = Tensor::f32(&[4, 4], buf);
        let da = dev.h2d(&bucketed).unwrap();
        let a_actual = vec![2usize, 3];
        let w = Tensor::f32(&[3, 4], (0..12).map(|i| 0.1 * i as f32).collect());
        let a_host = crop_box(&bucketed, &a_actual).unwrap();
        let key = lib.key_for(&a_host, &w).unwrap();
        let host = lib.matmul_with_key(&a_host, &w, key).unwrap();
        let (out, actual) = lib
            .matmul_device(
                GemmSrc::Dev { dt: &da, actual: &a_actual, zero_padded: false },
                GemmSrc::Host(&w),
                key,
            )
            .unwrap();
        assert!(lib.stats.prep_built >= 1, "device-side adaptation compiled");
        let back = lib.readback(&out, &actual).unwrap();
        assert_eq!(back, host, "dev->dev chain must be bit-exact vs host path");
    }

    #[test]
    fn zero_padded_device_operand_is_consumed_in_place() {
        // A GEMM result (exact zero pad) chained into a second GEMM with
        // matching entry extents moves zero h2d bytes for that operand.
        let dev = Arc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev.clone());
        let a = Tensor::f32(&[3, 3], (0..9).map(|i| i as f32 * 0.2).collect());
        let b = Tensor::f32(&[3, 3], (0..9).map(|i| 0.5 - i as f32 * 0.1).collect());
        let key = lib.key_for(&a, &b).unwrap();
        let (first, actual1) =
            lib.matmul_device(GemmSrc::Host(&a), GemmSrc::Host(&b), key).unwrap();
        let h2d_before = lib.stats.h2d_bytes;
        let prep_before = lib.stats.prep_built;
        // Chain: first · b, lhs consumed in place.
        let (second, actual2) = lib
            .matmul_device(
                GemmSrc::Dev { dt: &first, actual: &actual1, zero_padded: true },
                GemmSrc::Host(&b),
                key,
            )
            .unwrap();
        assert_eq!(lib.stats.prep_built, prep_before, "no adapter needed");
        // Only b was uploaded for the second call.
        assert_eq!(lib.stats.h2d_bytes - h2d_before, (16 * 16 * 4) as u64);
        let back = lib.readback(&second, &actual2).unwrap();
        let host1 = lib.matmul_with_key(&a, &b, key).unwrap();
        let host2 = lib.matmul_with_key(&host1, &b, key).unwrap();
        assert_eq!(back, host2);
    }
}
