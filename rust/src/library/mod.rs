//! Static-shape kernel library for compute-intensive ops (§4.5).
//!
//! GEMM/Conv-class ops never go through fusion codegen: like the paper
//! (cuBLAS/cuDNN), they are served by a library that "chooses the best
//! kernel according to different runtime shapes". The library holds
//! PJRT-compiled dot executables keyed by exact `(b, m, k, n)` — the vendor
//! analogue: a library call is always available for any shape and its
//! compilation cost is *not* part of the dynamic-compiler overhead story
//! (frameworks ship the library pre-built; we count library compiles
//! separately in the stats). Pre-generated AOT artifacts (from
//! `python/compile/aot.py`) can be registered on top and win selection,
//! mirroring the paper's hand-tuned per-shape entries.

use crate::codegen::BucketPolicy;
use crate::dhlo::DType;
use crate::runtime::buffers::BufferPool;
use crate::runtime::executor::{crop_box, pad_box};
use crate::runtime::pjrt::{Device, DeviceTensor, Executable};
use crate::runtime::tensor::Tensor;
use anyhow::{ensure, Result};
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Duration;

/// GEMM problem key: `[b?, m, k] · [b?, k, n]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmKey {
    pub batch: usize, // 0 = rank-2
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

#[derive(Debug, Clone, Default)]
pub struct LibraryStats {
    pub calls: u64,
    pub entries_built: u64,
    pub build_time: Duration,
    pub exec_time: Duration,
    pub flops: u64,
    pub pregen_hits: u64,
}

/// The kernel library.
pub struct GemmLibrary {
    device: Rc<Device>,
    entries: HashMap<GemmKey, Rc<Executable>>,
    /// Pre-generated (AOT) entries registered from artifacts; these take
    /// priority over on-demand built ones, like the paper's hand-tuned set.
    pregen: HashMap<GemmKey, Rc<Executable>>,
    /// Vendor libraries serve *any* shape from a fixed kernel set; we model
    /// that by bucketing the dynamic `m`/batch row dimension (k and n come
    /// from static weights). Without this, a dynamic workload would force
    /// one build per sequence length — exactly the pathology cuBLAS does
    /// not have.
    pub m_bucket: BucketPolicy,
    /// Pool for padded-operand scratch (the cached allocator of §4.2.2).
    pool: BufferPool,
    pub stats: LibraryStats,
}

impl GemmLibrary {
    pub fn new(device: Rc<Device>) -> Self {
        GemmLibrary {
            device,
            entries: HashMap::new(),
            pregen: HashMap::new(),
            m_bucket: BucketPolicy::MultipleOf(16),
            pool: BufferPool::new(),
            stats: LibraryStats::default(),
        }
    }

    /// Register a pre-generated executable (from an AOT artifact) for a
    /// specific problem shape.
    pub fn register_pregen(&mut self, key: GemmKey, exe: Executable) {
        self.pregen.insert(key, Rc::new(exe));
    }

    pub fn has_pregen(&self, key: &GemmKey) -> bool {
        self.pregen.contains_key(key)
    }

    fn dot_hlo(key: &GemmKey) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        if key.batch == 0 {
            let (m, k, n) = (key.m, key.k, key.n);
            let _ = write!(
                s,
                "HloModule gemm, entry_computation_layout={{(f32[{m},{k}]{{1,0}}, f32[{k},{n}]{{1,0}})->f32[{m},{n}]{{1,0}}}}\n\n\
                 ENTRY main {{\n  \
                 a = f32[{m},{k}]{{1,0}} parameter(0)\n  \
                 b = f32[{k},{n}]{{1,0}} parameter(1)\n  \
                 ROOT d = f32[{m},{n}]{{1,0}} dot(a, b), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
                 }}\n"
            );
        } else {
            let (bs, m, k, n) = (key.batch, key.m, key.k, key.n);
            let _ = write!(
                s,
                "HloModule bgemm, entry_computation_layout={{(f32[{bs},{m},{k}]{{2,1,0}}, f32[{bs},{k},{n}]{{2,1,0}})->f32[{bs},{m},{n}]{{2,1,0}}}}\n\n\
                 ENTRY main {{\n  \
                 a = f32[{bs},{m},{k}]{{2,1,0}} parameter(0)\n  \
                 b = f32[{bs},{k},{n}]{{2,1,0}} parameter(1)\n  \
                 ROOT d = f32[{bs},{m},{n}]{{2,1,0}} dot(a, b), lhs_batch_dims={{0}}, rhs_batch_dims={{0}}, lhs_contracting_dims={{2}}, rhs_contracting_dims={{1}}\n\
                 }}\n"
            );
        }
        s
    }

    fn entry_for(&mut self, key: GemmKey) -> Result<Rc<Executable>> {
        if let Some(e) = self.pregen.get(&key) {
            self.stats.pregen_hits += 1;
            return Ok(e.clone());
        }
        if let Some(e) = self.entries.get(&key) {
            return Ok(e.clone());
        }
        let hlo = Self::dot_hlo(&key);
        let name = format!("gemm_{}x{}x{}x{}", key.batch, key.m, key.k, key.n);
        let exe = self.device.compile_hlo_text_named(&name, &hlo)?;
        self.stats.entries_built += 1;
        self.stats.build_time += exe.compile_time;
        let e = Rc::new(exe);
        self.entries.insert(key, e.clone());
        Ok(e)
    }

    /// The concrete `(m, k, n)` problem plus batch count of `a · b`.
    fn problem_of(a: &Tensor, b: &Tensor) -> Result<((usize, usize, usize), usize)> {
        match (a.rank(), b.rank()) {
            (2, 2) => {
                ensure!(a.dims[1] == b.dims[0], "gemm: contracting mismatch");
                Ok(((a.dims[0], a.dims[1], b.dims[1]), 0usize))
            }
            (3, 3) => {
                ensure!(a.dims[0] == b.dims[0] && a.dims[2] == b.dims[1], "bgemm mismatch");
                Ok(((a.dims[1], a.dims[2], b.dims[2]), a.dims[0]))
            }
            (ra, rb) => anyhow::bail!("library matmul: ranks {ra}x{rb}"),
        }
    }

    /// Resolve the library entry key for a problem: exact pre-generated
    /// entries win over bucketing (the hand-tuned set, §4.5). Launch plans
    /// record this key so replays skip the derivation entirely.
    pub fn key_for(&self, a: &Tensor, b: &Tensor) -> Result<GemmKey> {
        let ((m, k, n), batch) = Self::problem_of(a, b)?;
        let exact_key = GemmKey { batch, m, k, n };
        Ok(if self.pregen.contains_key(&exact_key) {
            exact_key
        } else {
            GemmKey {
                batch,
                m: self.m_bucket.bucket(m),
                k: self.m_bucket.bucket(k),
                n: self.m_bucket.bucket(n),
            }
        })
    }

    /// Execute `a · b` through the library. Every dynamic problem dim is
    /// bucketed (vendor-library style: a fixed kernel set serves any
    /// shape): padded `m` rows and `n` columns are cropped from the result,
    /// and a zero-padded contracting `k` is mathematically exact (the extra
    /// products are zero).
    pub fn matmul(&mut self, a: &Tensor, b: &Tensor) -> Result<Tensor> {
        let key = self.key_for(a, b)?;
        self.matmul_with_key(a, b, key)
    }

    /// Pad both operands up to the entry's bucket extents (pool-backed
    /// scratch; `None` = aligned, passed by reference) and compute the
    /// bucket-shaped output dims. Shared by the host and device execution
    /// paths so their marshalling can never diverge.
    fn pad_for_entry(
        pool: &mut BufferPool,
        a: &Tensor,
        b: &Tensor,
        key: GemmKey,
        batch: usize,
    ) -> Result<(Option<Tensor>, Option<Tensor>, Vec<usize>)> {
        let mut pad2 = |t: &Tensor, d0: usize, d1: usize| -> Result<Option<Tensor>> {
            if t.rank() == 2 {
                if t.dims == [d0, d1] {
                    Ok(None)
                } else {
                    pad_box(t, &[d0, d1], Some(pool)).map(Some)
                }
            } else if t.dims[1] == d0 && t.dims[2] == d1 {
                Ok(None)
            } else {
                pad_box(t, &[batch, d0, d1], Some(pool)).map(Some)
            }
        };
        let a_pad = pad2(a, key.m, key.k)?;
        let b_pad = pad2(b, key.k, key.n)?;
        let out_dims = if batch == 0 {
            vec![key.m, key.n]
        } else {
            vec![batch, key.m, key.n]
        };
        Ok((a_pad, b_pad, out_dims))
    }

    /// Return pooled pad scratch and bump the per-call stats.
    fn finish_call(&mut self, pads: [Option<Tensor>; 2], batch: usize, flops_mkn: usize) {
        for t in pads.into_iter().flatten() {
            if let crate::runtime::tensor::Data::F32(v) = t.data {
                if v.capacity() > 0 {
                    self.pool.free_f32(v);
                }
            }
        }
        self.stats.calls += 1;
        self.stats.flops += (2 * batch.max(1) * flops_mkn) as u64;
    }

    /// Execute with a pre-resolved entry key (the launch-plan replay path:
    /// no shape derivation, no pregen probe, no bucket math).
    pub fn matmul_with_key(&mut self, a: &Tensor, b: &Tensor, key: GemmKey) -> Result<Tensor> {
        let ((m, k, n), batch) = Self::problem_of(a, b)?;
        let exe = self.entry_for(key)?;
        let t_call = std::time::Instant::now();
        let (a_pad, b_pad, out_dims) = Self::pad_for_entry(&mut self.pool, a, b, key, batch)?;
        let args = [a_pad.as_ref().unwrap_or(a), b_pad.as_ref().unwrap_or(b)];
        let out = exe.run(&args, &out_dims, DType::F32)?;
        self.finish_call([a_pad, b_pad], batch, m * k * n);
        let result = if (key.m, key.n) == (m, n) {
            Ok(out)
        } else if batch == 0 {
            crop_box(&out, &[m, n])
        } else {
            crop_box(&out, &[batch, m, n])
        };
        self.stats.exec_time += t_call.elapsed();
        result
    }

    /// Execute with a pre-resolved key, leaving the (bucket-shaped) result
    /// on device. Returns the device tensor plus the *actual* output dims.
    ///
    /// The pad region of the result is exact zeros (zero-padded operands:
    /// every padded row/column of the product is a sum of zero products),
    /// so downstream consumers may read the buffer directly when their
    /// bucket shape matches — including other GEMMs contracting over the
    /// padded axis.
    pub fn matmul_to_device(
        &mut self,
        a: &Tensor,
        b: &Tensor,
        key: GemmKey,
        device: &Device,
    ) -> Result<(DeviceTensor, Vec<usize>)> {
        let ((m, k, n), batch) = Self::problem_of(a, b)?;
        let exe = self.entry_for(key)?;
        let t_call = std::time::Instant::now();
        let (a_pad, b_pad, out_dims) = Self::pad_for_entry(&mut self.pool, a, b, key, batch)?;
        let da = device.h2d(a_pad.as_ref().unwrap_or(a))?;
        let db = device.h2d(b_pad.as_ref().unwrap_or(b))?;
        let out = exe.run_on_device(&[&da, &db], &out_dims, DType::F32)?;
        self.finish_call([a_pad, b_pad], batch, m * k * n);
        self.stats.exec_time += t_call.elapsed();
        let actual = if batch == 0 { vec![m, n] } else { vec![batch, m, n] };
        Ok((out, actual))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_matches_reference() {
        let dev = Rc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev);
        let a = Tensor::f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::f32(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let out = lib.matmul(&a, &b).unwrap();
        assert_eq!(out.as_f32().unwrap(), &[58., 64., 139., 154.]);
        assert_eq!(lib.stats.calls, 1);
        assert_eq!(lib.stats.flops, 2 * 2 * 3 * 2);
    }

    #[test]
    fn batched_gemm() {
        let dev = Rc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev);
        let a = Tensor::f32(&[2, 1, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::f32(&[2, 2, 1], vec![1., 1., 2., 2.]);
        let out = lib.matmul(&a, &b).unwrap();
        assert_eq!(out.dims, vec![2, 1, 1]);
        assert_eq!(out.as_f32().unwrap(), &[3., 14.]);
    }

    #[test]
    fn entries_are_reused() {
        let dev = Rc::new(Device::cpu().unwrap());
        let mut lib = GemmLibrary::new(dev);
        let a = Tensor::f32(&[2, 2], vec![1.; 4]);
        let b = Tensor::f32(&[2, 2], vec![1.; 4]);
        lib.matmul(&a, &b).unwrap();
        lib.matmul(&a, &b).unwrap();
        assert_eq!(lib.stats.entries_built, 1);
        assert_eq!(lib.stats.calls, 2);
    }
}
