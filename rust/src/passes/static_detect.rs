//! Static-subgraph detection for the mixed static/dynamic pipeline (§4.4).
//!
//! DISC lowers graphs to the *static* pipeline "when shapes are known at
//! compile time or the number of shapes is acceptable", because static
//! compilation produces better kernels (no masking, no bucket padding).
//! The detector classifies a module and recommends a pipeline; the
//! compiler's `Mode::Auto` acts on it.

use crate::dhlo::Module;

/// Pipeline recommendation for a module.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineChoice {
    /// Everything static: use exact-shape codegen (no masks, no buckets).
    Static,
    /// Dynamic dims present: bucket codegen + runtime shape calculation.
    Dynamic,
}

/// Classification report.
#[derive(Debug, Clone)]
pub struct StaticReport {
    pub choice: PipelineChoice,
    pub total_instrs: usize,
    pub dynamic_instrs: usize,
    /// Fraction of tensor ops whose output shape is fully static.
    pub static_fraction: f64,
}

/// Analyze a module and recommend a pipeline.
pub fn analyze(m: &Module) -> StaticReport {
    let mut total = 0usize;
    let mut dynamic = 0usize;
    for ins in &m.instrs {
        total += 1;
        if !ins.ty.canon(&m.syms).is_static() {
            dynamic += 1;
        }
    }
    let static_fraction = if total == 0 {
        1.0
    } else {
        (total - dynamic) as f64 / total as f64
    };
    let choice =
        if dynamic == 0 { PipelineChoice::Static } else { PipelineChoice::Dynamic };
    StaticReport { choice, total_instrs: total, dynamic_instrs: dynamic, static_fraction }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::shape::Dim;

    #[test]
    fn static_module_detected() {
        let mut b = Builder::new("s");
        let x = b.param(DType::F32, vec![Dim::Fixed(4)]);
        let y = b.unary(UnKind::Tanh, x);
        let m = b.finish(vec![y]);
        let r = analyze(&m);
        assert_eq!(r.choice, PipelineChoice::Static);
        assert_eq!(r.static_fraction, 1.0);
    }

    #[test]
    fn dynamic_module_detected() {
        let mut b = Builder::new("d");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let y = b.unary(UnKind::Tanh, x);
        let m = b.finish(vec![y]);
        let r = analyze(&m);
        assert_eq!(r.choice, PipelineChoice::Dynamic);
        assert!(r.dynamic_instrs >= 2);
    }

    #[test]
    fn refined_symbols_count_as_static() {
        // A symbol unified with a constant collapses to Fixed; modules made
        // fully static by refinement take the static pipeline.
        let mut b = Builder::new("r");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let f = b.param(DType::F32, vec![Dim::Fixed(8)]);
        let y = b.add(x, f).unwrap(); // refines s := 8
        let m = b.finish(vec![y]);
        let r = analyze(&m);
        assert_eq!(r.choice, PipelineChoice::Static);
    }
}
