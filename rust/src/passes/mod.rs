//! IR optimization passes run between bridging and fusion planning.
//!
//! DISC reuses the classic pipeline (the paper reuses XLA's building blocks
//! through MLIR-HLO): dead-code elimination, common-subexpression
//! elimination, and constant folding. Passes preserve the symbol table by
//! remapping the value ids embedded in shape expressions and size classes.

pub mod static_detect;

use crate::dhlo::{Instr, Module, Op};
use crate::runtime::reference::eval_op;
use crate::runtime::tensor::{Data, Tensor};
use anyhow::Result;
use std::collections::HashMap;

/// Rebuild a module keeping only instructions where `keep[id]`, remapping
/// operands, outputs, and symbol-table value references.
fn rebuild(m: &Module, keep: &[bool]) -> Module {
    let mut map: Vec<Option<usize>> = vec![None; m.instrs.len()];
    let mut instrs = Vec::new();
    for (id, ins) in m.instrs.iter().enumerate() {
        if keep[id] {
            map[id] = Some(instrs.len());
            let mut ni = ins.clone();
            ni.operands = ni.operands.iter().map(|&o| map[o].expect("operand kept")).collect();
            instrs.push(ni);
        }
    }
    let mut syms = m.syms.clone();
    syms.remap_values(&map);
    Module {
        name: m.name.clone(),
        instrs,
        params: m.params.clone(),
        outputs: m.outputs.iter().map(|&o| map[o].expect("output kept")).collect(),
        syms,
    }
}

/// Values referenced by symbol definitions of dims appearing anywhere in
/// the module (they must survive DCE: the shape program reads them).
fn shape_roots(m: &Module) -> Vec<usize> {
    let mut out = Vec::new();
    for i in 0..m.syms.len() {
        let mut deps = Vec::new();
        m.syms.def(crate::shape::SymId(i as u32)).value_deps(&mut deps);
        out.extend(deps);
    }
    out
}

/// Dead-code elimination: drop instructions unreachable from the outputs
/// (and from shape-expression roots of live symbols).
pub fn dce(m: &Module) -> Module {
    let mut live = vec![false; m.instrs.len()];
    let mut stack: Vec<usize> = m.outputs.clone();
    // Keep parameters: they define the external ABI.
    for (id, ins) in m.instrs.iter().enumerate() {
        if matches!(ins.op, Op::Param { .. }) {
            stack.push(id);
        }
    }
    // Symbols used by live values' types may read other values; over-
    // approximate by keeping all shape roots.
    stack.extend(shape_roots(m));
    while let Some(v) = stack.pop() {
        if v < live.len() && !live[v] {
            live[v] = true;
            stack.extend(m.instrs[v].operands.iter().copied());
        }
    }
    rebuild(m, &live)
}

fn cse_key(ins: &Instr) -> String {
    format!("{:?}|{:?}", ins.op, ins.operands)
}

/// Ops excluded from CSE/folding (side effects on the shape env, or
/// dynamic-twin identity that the signature machinery keys on).
fn is_pure(op: &Op) -> bool {
    !matches!(op, Op::Param { .. } | Op::Unique)
}

/// Common-subexpression elimination over pure ops.
pub fn cse(m: &Module) -> Module {
    let mut seen: HashMap<String, usize> = HashMap::new();
    let mut alias: Vec<usize> = (0..m.instrs.len()).collect();
    let mut keep = vec![true; m.instrs.len()];
    let mut rewritten = m.clone();
    for id in 0..rewritten.instrs.len() {
        // Rewrite operands through aliases first.
        let ops: Vec<usize> =
            rewritten.instrs[id].operands.iter().map(|&o| alias[o]).collect();
        rewritten.instrs[id].operands = ops;
        if !is_pure(&rewritten.instrs[id].op) {
            continue;
        }
        let key = cse_key(&rewritten.instrs[id]);
        match seen.get(&key) {
            Some(&prev) => {
                alias[id] = prev;
                keep[id] = false;
            }
            None => {
                seen.insert(key, id);
            }
        }
    }
    rewritten.outputs = rewritten.outputs.iter().map(|&o| alias[o]).collect();
    // Shape expressions may reference values replaced by an alias (e.g.
    // deduplicated index constants feeding a DSlice).
    let alias_map: Vec<Option<usize>> = alias.iter().map(|&a| Some(a)).collect();
    rewritten.syms.remap_values(&alias_map);
    rebuild(&rewritten, &keep)
}

/// Constant folding: pure ops whose operands are all constants and whose
/// output type is fully static are evaluated at compile time.
pub fn fold_constants(m: &Module) -> Result<Module> {
    let mut out = m.clone();
    for id in 0..out.instrs.len() {
        let ins = out.instrs[id].clone();
        if matches!(ins.op, Op::Param { .. } | Op::Const { .. } | Op::Unique) {
            continue;
        }
        let ty = ins.ty.canon(&out.syms);
        if !ty.is_static() {
            continue;
        }
        let consts: Option<Vec<Tensor>> = ins
            .operands
            .iter()
            .map(|&o| match &out.instrs[o].op {
                Op::Const { lit, dims } => Some(Tensor::from_literal(lit, dims)),
                _ => None,
            })
            .collect();
        let Some(operand_tensors) = consts else { continue };
        let dims: Vec<usize> = ty.dims.iter().map(|d| d.fixed().unwrap()).collect();
        let refs: Vec<&Tensor> = operand_tensors.iter().collect();
        let Ok(folded) = eval_op(&ins.op, &refs, &dims, ty.dtype) else { continue };
        let lit = match folded.data {
            Data::F32(v) => crate::dhlo::Literal::F32(v),
            Data::I64(v) => crate::dhlo::Literal::I64(v),
            Data::I32(v) => crate::dhlo::Literal::I32(v),
            Data::Pred(v) => crate::dhlo::Literal::Pred(v),
        };
        out.instrs[id] = Instr {
            op: Op::Const { lit, dims: dims.clone() },
            operands: vec![],
            ty,
            name: ins.name,
        };
    }
    // Folding may have orphaned the old constant operands.
    Ok(dce(&out))
}

/// The standard pipeline: fold → cse → dce, verified at each step.
pub fn optimize(m: &Module) -> Result<Module> {
    let m = fold_constants(m)?;
    crate::dhlo::verify::verify(&m)?;
    let m = cse(&m);
    crate::dhlo::verify::verify(&m)?;
    let m = dce(&m);
    crate::dhlo::verify::verify(&m)?;
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::runtime::reference::eval_module;
    use crate::shape::Dim;

    #[test]
    fn dce_removes_dead_chain() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let live = b.unary(UnKind::Tanh, x);
        let dead = b.unary(UnKind::Exp, x);
        let _dead2 = b.unary(UnKind::Abs, dead);
        let m = b.finish(vec![live]);
        let opt = dce(&m);
        assert_eq!(opt.instrs.len(), 2, "param + tanh survive");
        crate::dhlo::verify::verify(&opt).unwrap();
    }

    #[test]
    fn cse_merges_duplicates() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let a = b.unary(UnKind::Tanh, x);
        let c = b.unary(UnKind::Tanh, x);
        let y = b.add(a, c).unwrap();
        let m = b.finish(vec![y]);
        let opt = cse(&m);
        assert_eq!(opt.instrs.len(), 3, "one tanh eliminated");
        // Numerics preserved.
        let input = Tensor::f32(&[3], vec![0.1, 0.2, 0.3]);
        let r1 = eval_module(&m, &[input.clone()]).unwrap();
        let r2 = eval_module(&opt, &[input]).unwrap();
        assert!(r1.outputs[0].allclose(&r2.outputs[0], 1e-7, 1e-7).unwrap());
    }

    #[test]
    fn folding_collapses_constant_subgraph() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let c1 = b.scalar_f32(2.0);
        let c2 = b.scalar_f32(3.0);
        let c3 = b.mul(c1, c2).unwrap(); // foldable -> 6
        let c3b = b.broadcast_scalar_like(c3, x).unwrap(); // dynamic: not foldable
        let y = b.add(x, c3b).unwrap();
        let m = b.finish(vec![y]);
        let opt = optimize(&m).unwrap();
        // The mul is gone; a constant 6 remains.
        assert!(opt.instrs.iter().all(|i| !matches!(i.op, Op::Bin(crate::dhlo::BinKind::Mul))));
        let input = Tensor::f32(&[2], vec![1.0, 2.0]);
        let r = eval_module(&opt, &[input]).unwrap();
        assert_eq!(r.outputs[0].as_f32().unwrap(), &[7.0, 8.0]);
    }

    #[test]
    fn pipeline_preserves_dynamic_shape_machinery() {
        // dslice's index tensors are shape roots and must survive DCE.
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let st = b.i64_vec(&[1]);
        let li = b.i64_vec(&[3]);
        let sr = b.i64_vec(&[1]);
        let sl = b.dslice(x, st, li, sr).unwrap();
        let m = b.finish(vec![sl]);
        let opt = optimize(&m).unwrap();
        let input = Tensor::f32(&[5], vec![0., 1., 2., 3., 4.]);
        let r = eval_module(&opt, &[input]).unwrap();
        assert_eq!(r.outputs[0].as_f32().unwrap(), &[1., 2.]);
    }

    #[test]
    fn cse_respects_impure_ops() {
        let mut b = Builder::new("t");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::I64, vec![s]);
        let u1 = b.unique(x).unwrap();
        let u2 = b.unique(x).unwrap();
        let m = b.finish(vec![u1, u2]);
        let opt = cse(&m);
        let uniques =
            opt.instrs.iter().filter(|i| matches!(i.op, Op::Unique)).count();
        assert_eq!(uniques, 2, "unique has a distinct data-dep symbol; never merged");
    }
}
