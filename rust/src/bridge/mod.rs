//! Computation-graph bridging: framework graph → DHLO (§3, §4.1).
//!
//! Besides op-by-op lowering (with explicit broadcast materialization and
//! composite expansion for Softmax/LayerNorm), the bridge performs the
//! paper's *shape constraint collection from high-level ops* (§4.2.1,
//! second source). The canonical example is `tf.Split`: it lowers to
//! independent `DSlice`s whose result dims are fresh symbols — the fact
//! that all outputs share a shape would be lost, so the bridge injects
//! dimension-equality constraints across the outputs and against the
//! unsplit input axes. The fusion planner then sees through them.
//!
//! Lowering is the *only* producer of DHLO in the serving path (workload
//! graphs and `disc import`ed JSON both come through here), which is what
//! makes the collected constraint set trustworthy downstream: `SymEnv`
//! re-checks it per request at binding time. Module map:
//! `docs/architecture.md`.

use crate::dhlo::{Builder, Literal, Module, ValueId};
use crate::graph::{GOp, Graph};
use crate::shape::{Dim, ShapeExpr};
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;

/// Lower a framework graph to a DHLO module.
pub fn lower(g: &Graph) -> Result<Module> {
    let mut b = Builder::new(g.name.clone());
    let mut env: HashMap<(usize, usize), ValueId> = HashMap::new();
    let mut param_count = 0usize;

    for (nid, node) in g.nodes.iter().enumerate() {
        let ins: Vec<ValueId> = node
            .inputs
            .iter()
            .map(|e| {
                env.get(&(e.node, e.port))
                    .copied()
                    .ok_or_else(|| anyhow::anyhow!("node {} input missing", node.name))
            })
            .collect::<Result<_>>()?;
        let outs: Vec<ValueId> = lower_node(&mut b, node, &ins, &mut param_count)
            .with_context(|| format!("lowering node '{}' ({})", node.name, node.op.name()))?;
        ensure!(outs.len() == node.op.num_outputs(), "output arity mismatch");
        for (port, v) in outs.into_iter().enumerate() {
            b.set_name(v, format!("{}:{port}", node.name));
            env.insert((nid, port), v);
        }
    }

    let outputs: Vec<ValueId> = g
        .outputs
        .iter()
        .map(|e| {
            env.get(&(e.node, e.port))
                .copied()
                .ok_or_else(|| anyhow::anyhow!("graph output missing"))
        })
        .collect::<Result<_>>()?;
    let m = b.finish(outputs);
    crate::dhlo::verify::verify(&m)?;
    Ok(m)
}

/// Insert broadcasts so the two operands share a shape (numpy trailing-axis
/// rules restricted to the cases frameworks actually emit).
fn broadcast_pair(b: &mut Builder, x: ValueId, y: ValueId) -> Result<(ValueId, ValueId)> {
    let (rx, ry) = (b.m.ty(x).rank(), b.m.ty(y).rank());
    if rx == ry {
        return Ok((x, y));
    }
    if rx == 0 {
        let xb = b.broadcast_scalar_like(x, y)?;
        return Ok((xb, y));
    }
    if ry == 0 {
        let yb = b.broadcast_scalar_like(y, x)?;
        return Ok((x, yb));
    }
    if rx == 1 && ry > 1 {
        let xb = b.broadcast_row_like(x, y)?;
        return Ok((xb, y));
    }
    if ry == 1 && rx > 1 {
        let yb = b.broadcast_row_like(y, x)?;
        return Ok((x, yb));
    }
    bail!("unsupported broadcast ranks {rx} vs {ry}")
}

/// Build an `s64[rank]` index tensor from per-axis scalar values, where each
/// scalar is either a constant or a host-computed value (GetDimSize math).
fn pack_index_tensor(b: &mut Builder, parts: &[ValueId]) -> Result<ValueId> {
    // All-constant fast path.
    let consts: Option<Vec<i64>> = parts
        .iter()
        .map(|&v| match &b.m.instrs[v].op {
            crate::dhlo::Op::Const { lit: Literal::I64(vals), .. } => Some(vals[0]),
            _ => None,
        })
        .collect();
    if let Some(vals) = consts {
        return Ok(b.i64_vec(&vals));
    }
    let mut ones: Vec<ValueId> = Vec::with_capacity(parts.len());
    for &p in parts {
        ones.push(b.reshape(p, vec![Dim::Fixed(1)])?);
    }
    b.concat(&ones, 0)
}

fn lower_node(
    b: &mut Builder,
    node: &crate::graph::Node,
    ins: &[ValueId],
    param_count: &mut usize,
) -> Result<Vec<ValueId>> {
    Ok(match &node.op {
        GOp::Placeholder { dtype, dims } => {
            let p = *param_count;
            *param_count += 1;
            let d: Vec<Dim> = dims
                .iter()
                .enumerate()
                .map(|(axis, &d)| {
                    if d < 0 {
                        b.dyn_dim(format!("{}_{axis}", node.name), p, axis)
                    } else {
                        Dim::Fixed(d as usize)
                    }
                })
                .collect();
            vec![b.param(*dtype, d)]
        }
        GOp::Const { lit, dims } => vec![b.constant(lit.clone(), dims)],
        GOp::Unary(k) => vec![b.unary(*k, ins[0])],
        GOp::Binary(k) => {
            let (x, y) = broadcast_pair(b, ins[0], ins[1])?;
            vec![b.binary(*k, x, y)?]
        }
        GOp::Compare(d) => {
            let (x, y) = broadcast_pair(b, ins[0], ins[1])?;
            vec![b.compare(*d, x, y)?]
        }
        GOp::Select => vec![b.select(ins[0], ins[1], ins[2])?],
        GOp::Cast { to } => vec![b.convert(ins[0], *to)],
        GOp::Scale { c } => {
            let s = b.scalar_f32(*c);
            let sb = b.broadcast_scalar_like(s, ins[0])?;
            vec![b.mul(ins[0], sb)?]
        }
        GOp::MatMul => vec![b.dot(ins[0], ins[1])?],
        GOp::Softmax => vec![b.softmax_last(ins[0])?],
        GOp::LayerNorm { eps } => vec![b.layernorm_last(ins[0], ins[1], ins[2], *eps)?],
        GOp::BiasAdd => {
            let bias = b.broadcast_row_like(ins[1], ins[0])?;
            vec![b.add(ins[0], bias)?]
        }
        GOp::Transpose { perm } => vec![b.transpose(ins[0], perm.clone())?],
        GOp::Concat { axis } => vec![b.concat(ins, *axis)?],
        GOp::Reduce { kind, axes } => vec![b.reduce(*kind, ins[0], axes.clone())?],
        GOp::Gather { axis } => vec![b.gather(ins[0], ins[1], *axis)?],
        GOp::Unique => vec![b.unique(ins[0])?],
        GOp::Pad { low, high, value } => {
            let v = b.scalar_f32(*value);
            vec![b.pad(ins[0], v, low.clone(), high.clone())?]
        }
        GOp::Reshape { dims } => vec![lower_reshape(b, ins[0], dims)?],
        GOp::Slice { begin, size } => vec![lower_slice(b, ins[0], begin, size)?],
        GOp::Split { axis, num } => lower_split(b, ins[0], *axis, *num)?,
    })
}

/// TF-style reshape with at most one `-1` (inferred) dim. With dynamic
/// inputs the inferred dim becomes a symbol `total / known`.
fn lower_reshape(b: &mut Builder, x: ValueId, dims: &[i64]) -> Result<ValueId> {
    let in_dims = b.m.ty(x).dims.clone();
    ensure!(dims.iter().filter(|&&d| d == -1).count() <= 1, "reshape: multiple -1 dims");
    let known: i64 = dims.iter().filter(|&&d| d >= 0).product::<i64>().max(1);
    let mut out: Vec<Dim> = Vec::with_capacity(dims.len());
    for &d in dims {
        if d >= 0 {
            out.push(Dim::Fixed(d as usize));
        } else if in_dims.iter().all(|dd| !dd.is_dynamic()) {
            let total: usize = in_dims.iter().map(|dd| dd.fixed().unwrap()).product();
            out.push(Dim::Fixed(total / known as usize));
        } else {
            // total(symbolic) / known
            let total = in_dims
                .iter()
                .map(|&dd| ShapeExpr::Dim(dd))
                .reduce(ShapeExpr::mul)
                .unwrap_or(ShapeExpr::Const(1));
            let expr = ShapeExpr::ceil_div(total, ShapeExpr::Const(known));
            out.push(Dim::Sym(b.m.syms.fresh(format!("rsh{}", b.m.instrs.len()), expr)));
        }
    }
    b.reshape(x, out)
}

/// TF-style slice (`begin` + `size`, `-1` = to end). Static inputs lower to
/// HLO `Slice`; dynamic inputs take the DHLO `DSlice` twin with host-side
/// index tensors (figure 2 of the paper).
fn lower_slice(b: &mut Builder, x: ValueId, begin: &[i64], size: &[i64]) -> Result<ValueId> {
    let in_dims = b.m.ty(x).dims.clone();
    let rank = in_dims.len();
    ensure!(begin.len() == rank && size.len() == rank, "slice: rank mismatch");
    let all_static = in_dims.iter().all(|d| !d.is_dynamic());
    if all_static {
        let mut limits = Vec::with_capacity(rank);
        for a in 0..rank {
            let n = in_dims[a].fixed().unwrap() as i64;
            limits.push(if size[a] < 0 { n } else { begin[a] + size[a] });
        }
        return b.slice(x, begin.to_vec(), limits, vec![1; rank]);
    }
    // Dynamic: build index tensors on the host.
    let mut start_parts = Vec::with_capacity(rank);
    let mut limit_parts = Vec::with_capacity(rank);
    for a in 0..rank {
        start_parts.push(b.scalar_i64(begin[a]));
        if size[a] < 0 {
            let lim = b.get_dim_size(x, a)?;
            limit_parts.push(lim);
        } else {
            limit_parts.push(b.scalar_i64(begin[a] + size[a]));
        }
    }
    let starts = pack_index_tensor(b, &start_parts)?;
    let limits = pack_index_tensor(b, &limit_parts)?;
    let strides = b.i64_vec(&vec![1i64; rank]);
    b.dslice(x, starts, limits, strides)
}

/// `tf.Split`: `num` equal parts along `axis`, with constraint injection.
fn lower_split(b: &mut Builder, x: ValueId, axis: usize, num: usize) -> Result<Vec<ValueId>> {
    let in_dims = b.m.ty(x).dims.clone();
    let rank = in_dims.len();
    ensure!(axis < rank, "split: axis out of range");
    ensure!(num >= 1, "split: num >= 1");

    let mut outs = Vec::with_capacity(num);
    match b.m.syms.canon_dim(in_dims[axis]) {
        Dim::Fixed(n) => {
            ensure!(n % num == 0, "split: {n} not divisible by {num}");
            let part = (n / num) as i64;
            for i in 0..num {
                let mut starts = vec![0i64; rank];
                let mut limits: Vec<i64> = Vec::with_capacity(rank);
                for a in 0..rank {
                    if a == axis {
                        starts[a] = part * i as i64;
                        limits.push(part * (i as i64 + 1));
                    } else if let Dim::Fixed(d) = b.m.syms.canon_dim(in_dims[a]) {
                        limits.push(d as i64);
                    } else {
                        // Mixed: fall back to the dynamic path entirely.
                        return lower_split_dynamic(b, x, axis, num);
                    }
                }
                outs.push(b.slice(x, starts, limits, vec![1; rank])?);
            }
        }
        Dim::Sym(_) => return lower_split_dynamic(b, x, axis, num),
    }
    inject_split_constraints(b, x, &outs, axis);
    Ok(outs)
}

fn lower_split_dynamic(
    b: &mut Builder,
    x: ValueId,
    axis: usize,
    num: usize,
) -> Result<Vec<ValueId>> {
    let rank = b.m.ty(x).rank();
    // part = dim(axis) / num, computed on the host.
    let dim_axis = b.get_dim_size(x, axis)?;
    let num_c = b.scalar_i64(num as i64);
    let part = b.div(dim_axis, num_c)?;

    let mut outs = Vec::with_capacity(num);
    for i in 0..num {
        let i_c = b.scalar_i64(i as i64);
        let i1_c = b.scalar_i64(i as i64 + 1);
        let start_axis = b.mul(part, i_c)?;
        let limit_axis = b.mul(part, i1_c)?;
        let mut start_parts = Vec::with_capacity(rank);
        let mut limit_parts = Vec::with_capacity(rank);
        for a in 0..rank {
            if a == axis {
                start_parts.push(start_axis);
                limit_parts.push(limit_axis);
            } else {
                start_parts.push(b.scalar_i64(0));
                let lim = b.get_dim_size(x, a)?;
                limit_parts.push(lim);
            }
        }
        let starts = pack_index_tensor(b, &start_parts)?;
        let limits = pack_index_tensor(b, &limit_parts)?;
        let strides = b.i64_vec(&vec![1i64; rank]);
        outs.push(b.dslice(x, starts, limits, strides)?);
    }
    inject_split_constraints(b, x, &outs, axis);
    Ok(outs)
}

/// The paper's §4.2.1 example: after lowering, the `DSlice`s' result dims
/// are unrelated fresh symbols. Re-inject what `Split` semantics guarantee:
/// all outputs share a shape, and non-split axes equal the input's.
fn inject_split_constraints(b: &mut Builder, x: ValueId, outs: &[ValueId], axis: usize) {
    let in_dims = b.m.ty(x).dims.clone();
    let rank = in_dims.len();
    for w in 1..outs.len() {
        // Pairwise dim equality across sibling outputs.
        for a in 0..rank {
            let d0 = b.m.ty(outs[0]).dims[a];
            let dw = b.m.ty(outs[w]).dims[a];
            b.m.inject_dim_equality(d0, dw);
        }
        b.m.inject_size_equality(outs[0], outs[w]);
    }
    for out in outs {
        for a in 0..rank {
            if a != axis {
                let dout = b.m.ty(*out).dims[a];
                b.m.inject_dim_equality(dout, in_dims[a]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dhlo::{BinKind, DType, UnKind};
    use crate::graph::GraphBuilder;
    use crate::runtime::reference::eval_module;
    use crate::runtime::tensor::Tensor;

    #[test]
    fn lowers_mlp_with_bias_broadcast() {
        let mut gb = GraphBuilder::new("mlp");
        let x = gb.placeholder("x", DType::F32, &[-1, 4]);
        let w = gb.weight("w", &[4, 4], 1);
        let bias = gb.weight("b", &[4], 2);
        let h = gb.matmul("h", x, w);
        let hb = gb.bias_add("hb", h, bias);
        let y = gb.unary("y", UnKind::Relu, hb);
        let g = gb.finish(&[y]);
        let m = lower(&g).unwrap();
        let r = eval_module(&m, &[Tensor::f32(&[3, 4], vec![0.1; 12])]).unwrap();
        assert_eq!(r.outputs[0].dims, vec![3, 4]);
    }

    #[test]
    fn split_on_dynamic_axis_injects_equalities() {
        let mut gb = GraphBuilder::new("split");
        let x = gb.placeholder("x", DType::F32, &[-1, 8]);
        let parts = gb.split("sp", x, 0, 2);
        let y = gb.binary("merge", BinKind::Add, parts[0], parts[1]);
        let g = gb.finish(&[y]);
        let m = lower(&g).unwrap();
        // The add typechecks only because the injected constraints unified
        // the two DSlice output shapes. Numerics:
        let input = Tensor::f32(&[6, 8], (0..48).map(|i| i as f32).collect());
        let r = eval_module(&m, &[input]).unwrap();
        assert_eq!(r.outputs[0].dims, vec![3, 8]);
        // top half + bottom half
        assert_eq!(r.outputs[0].as_f32().unwrap()[0], 0.0 + 24.0);
    }

    #[test]
    fn split_static_axis_uses_plain_slices() {
        // Fully static input: the split lowers to plain HLO slices.
        let mut gb = GraphBuilder::new("split");
        let x = gb.placeholder("x", DType::F32, &[2, 8]);
        let parts = gb.split("sp", x, 1, 2);
        let y = gb.binary("merge", BinKind::Mul, parts[0], parts[1]);
        let g = gb.finish(&[y]);
        let m = lower(&g).unwrap();
        assert!(m.instrs.iter().any(|i| matches!(i.op, crate::dhlo::Op::Slice { .. })));
        assert!(!m.instrs.iter().any(|i| matches!(i.op, crate::dhlo::Op::DSlice)));
        let input = Tensor::f32(&[2, 8], (0..16).map(|i| i as f32).collect());
        let r = eval_module(&m, &[input]).unwrap();
        assert_eq!(r.outputs[0].dims, vec![2, 4]);
        assert_eq!(r.outputs[0].as_f32().unwrap()[0], 0.0 * 4.0);
        assert_eq!(r.outputs[0].as_f32().unwrap()[1], 1.0 * 5.0);
    }

    #[test]
    fn split_constraints_enable_sibling_fusion() {
        // Without injected constraints the two dslice outputs would have
        // unrelated symbolic shapes and `add` could not even typecheck;
        // with them, the downstream elementwise chain fuses into one group.
        let mut gb = GraphBuilder::new("fusetest");
        let x = gb.placeholder("x", DType::F32, &[-1, 8]);
        let parts = gb.split("sp", x, 0, 2);
        let s = gb.binary("s", BinKind::Add, parts[0], parts[1]);
        let t = gb.unary("t", UnKind::Tanh, s);
        let g = gb.finish(&[t]);
        let m = lower(&g).unwrap();
        let plan = crate::fusion::plan(&m, &crate::fusion::FusionOptions::default());
        let gid_s = plan.membership[m.outputs[0]];
        assert!(gid_s.is_some());
        let group = &plan.groups[gid_s.unwrap()];
        assert!(group.len() >= 2, "add+tanh fuse across split outputs");
    }

    #[test]
    fn dynamic_reshape_infers_symbolic_dim() {
        let mut gb = GraphBuilder::new("rsh");
        let x = gb.placeholder("x", DType::F32, &[-1, 2, 4]);
        let y = gb.reshape("y", x, &[-1, 8]);
        let g = gb.finish(&[y]);
        let m = lower(&g).unwrap();
        let input = Tensor::f32(&[3, 2, 4], vec![1.0; 24]);
        let r = eval_module(&m, &[input]).unwrap();
        assert_eq!(r.outputs[0].dims, vec![3, 8]);
    }

    #[test]
    fn dynamic_slice_to_end() {
        let mut gb = GraphBuilder::new("sl");
        let x = gb.placeholder("x", DType::F32, &[-1, 4]);
        let y = gb.add(
            "sl",
            GOp::Slice { begin: vec![1, 0], size: vec![-1, 2] },
            &[x],
        );
        let g = gb.finish(&[y]);
        let m = lower(&g).unwrap();
        let input = Tensor::f32(&[4, 4], (0..16).map(|i| i as f32).collect());
        let r = eval_module(&m, &[input]).unwrap();
        assert_eq!(r.outputs[0].dims, vec![3, 2]);
        assert_eq!(r.outputs[0].as_f32().unwrap()[0], 4.0);
    }

    #[test]
    fn end_to_end_through_compiler() {
        // Bridge → optimize → fuse → program → PJRT, numerics vs reference.
        let mut gb = GraphBuilder::new("e2e");
        let x = gb.placeholder("x", DType::F32, &[-1, 8]);
        let w = gb.weight("w", &[8, 8], 3);
        let gma = gb.weight("g", &[8], 4);
        let bta = gb.weight("bt", &[8], 5);
        let h = gb.matmul("h", x, w);
        let act = gb.unary("act", UnKind::Gelu, h);
        let ln = gb.layernorm("ln", act, gma, bta);
        let sm = gb.softmax("sm", ln);
        let g = gb.finish(&[sm]);
        let m = lower(&g).unwrap();

        let compiler = crate::compiler::DiscCompiler::new().unwrap();
        let mut model = compiler
            .compile(m, &crate::compiler::CompileOptions::mode(crate::compiler::Mode::Disc))
            .unwrap();
        let mut rng = crate::util::prng::Prng::new(9);
        for n in [2usize, 5, 12] {
            let input = Tensor::f32(&[n, 8], rng.fill_f32(n * 8, 1.0));
            let got = model.run(&[input.clone()]).unwrap();
            let want = eval_module(model.module(), &[input]).unwrap();
            assert!(got.outputs[0].allclose(&want.outputs[0], 1e-4, 1e-4).unwrap());
        }
    }
}
