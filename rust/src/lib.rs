//! DISC: a dynamic shape compiler for machine learning workloads.
//!
//! Reproduction of Zhu et al., EuroMLSys '21, as a Rust compiler + runtime
//! over PJRT, with build-time JAX/Pallas artifacts. See DESIGN.md.

pub mod bench;
pub mod bridge;
pub mod cli;
pub mod codegen;
pub mod compiler;
pub mod coordinator;
pub mod dhlo;
pub mod fusion;
pub mod graph;
pub mod library;
pub mod passes;
pub mod program;
pub mod runtime;
pub mod shape;
pub mod sim;
pub mod util;
pub mod workloads;
pub mod vm;
