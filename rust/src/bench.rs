//! Minimal benchmark harness (criterion is not in the vendored registry).
//!
//! Provides warmup + repeated measurement with median/mean/min reporting,
//! and fixed-width table printing for the paper-table benches. Used by
//! every target under `rust/benches/` (each sets `harness = false`).

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Where a bench target writes its `BENCH_*.json` artifact: the repo root
/// (one directory above the cargo manifest), regardless of the working
/// directory the bench was launched from. Keeps the perf trajectory
/// trackable in-tree — every bench and every CI invocation lands artifacts
/// in the same place.
pub fn artifact_path(name: &str) -> PathBuf {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).join(name)
}

/// Result of measuring one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
}

/// Run `f` `iters` times after `warmup` runs; report robust statistics.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / samples.len().max(1) as u32;
    let min = samples[0];
    Measurement { name: name.to_string(), iters, median, mean, min }
}

/// Fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", padded.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Format a ratio as `N.NNx`.
pub fn speedup(baseline_ms: f64, measured_ms: f64) -> String {
    if measured_ms <= 0.0 {
        return "inf".into();
    }
    format!("{:.2}x", baseline_ms / measured_ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut calls = 0;
        let m = measure("t", 2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(m.iters, 5);
        assert!(m.min <= m.median);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // no panic; visual check in bench output
    }

    #[test]
    fn speedup_format() {
        assert_eq!(speedup(10.0, 5.0), "2.00x");
        assert_eq!(speedup(10.0, 0.0), "inf");
    }
}
