//! Minimal benchmark harness (criterion is not in the vendored registry).
//!
//! Provides warmup + repeated measurement with median/mean/min reporting,
//! and fixed-width table printing for the paper-table benches. Used by
//! every target under `rust/benches/` (each sets `harness = false`).

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Where a bench target writes its `BENCH_*.json` artifact: the repo root
/// (one directory above the cargo manifest), regardless of the working
/// directory the bench was launched from. Keeps the perf trajectory
/// trackable in-tree — every bench and every CI invocation lands artifacts
/// in the same place.
pub fn artifact_path(name: &str) -> PathBuf {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    manifest.parent().unwrap_or(manifest).join(name)
}

/// Result of measuring one benchmark case.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }
}

/// Run `f` `iters` times after `warmup` runs; report robust statistics.
pub fn measure<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / samples.len().max(1) as u32;
    let min = samples[0];
    Measurement { name: name.to_string(), iters, median, mean, min }
}

/// Fixed-width table printer for bench reports.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", padded.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        println!("|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            line(row);
        }
    }
}

/// Seeded Zipf-skewed length stream over `[lo, hi]`: rank 1 is the most
/// frequent length, probabilities fall off as `rank^-exponent`. The
/// ranks walk outward from `lo` so small lengths dominate — the classic
/// serving traffic shape (most requests short, a heavy tail of long
/// ones) that adaptive bucketing exploits. Deterministic per seed: tests
/// and benches that gate on it print the seed so a failure reproduces
/// with the same stream.
pub fn zipf_lengths(seed: u64, n: usize, lo: usize, hi: usize, exponent: f64) -> Vec<usize> {
    assert!(lo <= hi, "zipf_lengths wants lo <= hi");
    let m = hi - lo + 1;
    // CDF inversion over the finite rank set.
    let weights: Vec<f64> =
        (1..=m).map(|rank| 1.0 / (rank as f64).powf(exponent.max(0.0))).collect();
    let total: f64 = weights.iter().sum();
    let mut cdf = Vec::with_capacity(m);
    let mut acc = 0.0;
    for w in &weights {
        acc += w / total;
        cdf.push(acc);
    }
    let mut rng = crate::util::prng::Prng::new(seed);
    (0..n)
        .map(|_| {
            let u = rng.f32() as f64;
            let rank = cdf.partition_point(|&c| c < u).min(m - 1);
            lo + rank
        })
        .collect()
}

/// Format a ratio as `N.NNx`.
pub fn speedup(baseline_ms: f64, measured_ms: f64) -> String {
    if measured_ms <= 0.0 {
        return "inf".into();
    }
    format!("{:.2}x", baseline_ms / measured_ms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters() {
        let mut calls = 0;
        let m = measure("t", 2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(m.iters, 5);
        assert!(m.min <= m.median);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // no panic; visual check in bench output
    }

    #[test]
    fn speedup_format() {
        assert_eq!(speedup(10.0, 5.0), "2.00x");
        assert_eq!(speedup(10.0, 0.0), "inf");
    }

    #[test]
    fn zipf_lengths_is_seeded_skewed_and_bounded() {
        let a = zipf_lengths(42, 500, 10, 90, 1.2);
        let b = zipf_lengths(42, 500, 10, 90, 1.2);
        assert_eq!(a, b, "same seed must reproduce the same stream");
        assert!(a.iter().all(|&l| (10..=90).contains(&l)));
        // Skew: the bottom quartile of the range holds most of the mass.
        let small = a.iter().filter(|&&l| l <= 30).count();
        assert!(small * 2 > a.len(), "zipf stream must skew small: {small}/500");
        // Different seed, different stream.
        assert_ne!(a, zipf_lengths(43, 500, 10, 90, 1.2));
        // Degenerate range collapses to the single length.
        assert!(zipf_lengths(7, 16, 5, 5, 1.0).iter().all(|&l| l == 5));
    }
}
