//! Device cost model — the NVIDIA T4 stand-in (DESIGN.md §3).
//!
//! The paper's timing claims are functions of hardware-independent
//! quantities this runtime measures exactly: kernel-launch counts, off-chip
//! bytes, library-call FLOPs, and host-side (CPU) control time. The cost
//! model converts the counts into T4-scale milliseconds so the breakdown
//! tables have the same structure as the paper's Table 2 (comp-bound /
//! mem-bound / CPU / E2E). Host time is *measured*, not modeled — the
//! interpretation-overhead comparison is real; only device kernel time is
//! translated from counts.
//!
//! The transfer counters (`h2d_bytes`/`d2h_bytes`, fed by the executor and
//! the library's `LibraryStats`) are deliberately *not* folded into the
//! modeled device time: they quantify the PCIe traffic the device-resident
//! tiers remove, and the benches report them as their own column.

use crate::runtime::metrics::RunMetrics;

/// Cost-model parameters (defaults approximate a T4 + CUDA 10 testbed).
#[derive(Debug, Clone)]
pub struct GpuModel {
    /// Per-kernel launch overhead, µs (driver + dispatch).
    pub launch_overhead_us: f64,
    /// Effective HBM bandwidth for memory-bound kernels, GB/s.
    pub hbm_bw_gbps: f64,
    /// Sustained FP32 throughput for library GEMMs, TFLOP/s.
    pub gemm_tflops: f64,
    /// Per-library-call overhead, µs (cuBLAS dispatch).
    pub lib_overhead_us: f64,
    /// How much of measured host wall time to charge as CPU time
    /// (1.0 = report the measurement as-is).
    pub cpu_scale: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        // T4: 320 GB/s peak HBM (≈70% achievable), 8.1 TFLOPs FP32 peak
        // (≈60% sustained for mid-size GEMMs), ~5 µs per launch on CUDA 10.
        GpuModel {
            launch_overhead_us: 5.0,
            hbm_bw_gbps: 220.0,
            gemm_tflops: 4.8,
            lib_overhead_us: 8.0,
            cpu_scale: 1.0,
        }
    }
}

/// Modeled breakdown, in milliseconds (the paper's Table 2 columns).
#[derive(Debug, Clone, Default)]
pub struct SimBreakdown {
    pub comp_bound_ms: f64,
    pub mem_bound_ms: f64,
    pub cpu_ms: f64,
    pub e2e_ms: f64,
}

impl GpuModel {
    /// Convert run metrics into the modeled breakdown.
    pub fn breakdown(&self, m: &RunMetrics) -> SimBreakdown {
        let mem_bound_ms = m.mem_kernels as f64 * self.launch_overhead_us / 1e3
            + m.mem_bytes as f64 / (self.hbm_bw_gbps * 1e9) * 1e3;
        let comp_bound_ms = m.lib_calls as f64 * self.lib_overhead_us / 1e3
            + m.flops as f64 / (self.gemm_tflops * 1e12) * 1e3
            + m.lib_bytes as f64 / (self.hbm_bw_gbps * 1e9) * 1e3;
        let cpu_ms = m.cpu_time().as_secs_f64() * 1e3 * self.cpu_scale;
        SimBreakdown {
            comp_bound_ms,
            mem_bound_ms,
            cpu_ms,
            // Device work overlaps poorly on small kernels (the paper's
            // regime); model E2E as the serialized sum, like Table 2 rows.
            e2e_ms: comp_bound_ms + mem_bound_ms + cpu_ms,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn metrics(kernels: u64, bytes: u64, lib: u64, flops: u64) -> RunMetrics {
        RunMetrics {
            mem_kernels: kernels,
            mem_bytes: bytes,
            lib_calls: lib,
            flops,
            total_time: Duration::from_millis(10),
            ..Default::default()
        }
    }

    #[test]
    fn fewer_launches_less_mem_time() {
        let model = GpuModel::default();
        let fused = model.breakdown(&metrics(10, 1 << 20, 2, 1 << 20));
        let eager = model.breakdown(&metrics(60, 3 << 20, 2, 1 << 20));
        assert!(fused.mem_bound_ms < eager.mem_bound_ms);
        assert!((fused.comp_bound_ms - eager.comp_bound_ms).abs() < 1e-9);
    }

    #[test]
    fn launch_overhead_dominates_small_kernels() {
        let model = GpuModel::default();
        // 1000 launches moving 1 KiB each: overhead >> bandwidth.
        let b = model.breakdown(&metrics(1000, 1000 * 1024, 0, 0));
        let overhead_ms = 1000.0 * 5.0 / 1e3;
        assert!(b.mem_bound_ms > overhead_ms * 0.9);
        assert!(b.mem_bound_ms < overhead_ms * 1.5);
    }

    #[test]
    fn cpu_time_is_measured_passthrough() {
        let model = GpuModel::default();
        let mut m = metrics(1, 0, 0, 0);
        m.total_time = Duration::from_millis(8);
        m.kernel_time = Duration::from_millis(3);
        let b = model.breakdown(&m);
        assert!((b.cpu_ms - 5.0).abs() < 0.01);
    }
}
