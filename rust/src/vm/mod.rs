//! Nimble-like VM baseline (§2, §5.2 comparator).
//!
//! Nimble executes dynamic-shape graphs by *interpreting* a pre-built VM:
//! runtime control flow walks the graph, re-derives shapes per node visit,
//! dispatches ops through an opcode table, and manages buffers by
//! refcounting. DISC's claim (paper Table 2) is that compile-time-generated
//! runtime flow removes this interpretation overhead — the CPU-time row.
//!
//! This module deliberately implements that interpreted architecture over
//! the *same* kernels, library, and bucket cache as the DISC executor, so
//! every difference in the measured CPU column comes from the control-flow
//! architecture, not from kernel quality:
//!
//! * per-node dynamic dispatch through a boxed-handler opcode table;
//! * per-visit shape resolution and group-metadata recomputation (external
//!   inputs, symbol lists are *not* precomputed);
//! * refcount-based deallocation with per-operand hash updates;
//! * per-run setup of the instruction/registers maps.
//!
//! Nimble's fusion is driven by shape propagation without DISC's collected
//! constraints, so callers pass a `FusionOptions { use_constraints: false }`
//! plan (see `compiler::Mode::VmNimble`); with fewer/lazier fusions it also
//! reproduces the kernel-count gap of Table 3.
//!
//! The VM deliberately has *no* launch-plan cache, no device-resident
//! chaining, and no weight cache — those are the DISC executor's tiers
//! (`docs/runtime.md`); giving them to the baseline would measure nothing.

use crate::codegen::KernelCache;
use crate::dhlo::{Module, Op, ValueId};
use crate::fusion::signature::{external_inputs, signature};
use crate::fusion::FusionPlan;
use crate::library::GemmLibrary;
use crate::runtime::executor::{crop_box, pad_box};
use crate::runtime::metrics::RunMetrics;
use crate::runtime::reference::eval_op;
use crate::runtime::shape_env::SymEnv;
use crate::shape::SymId;
use crate::runtime::tensor::Tensor;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

/// Opcode classes the VM dispatches on (a small interpreted ISA, like
/// Nimble's VM instructions).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum OpCode {
    Nop,
    HostEval,
    Bitcast,
    DeviceKernel,
    FusedKernel,
    Library,
}

type Handler = Box<dyn Fn(&mut VmState, &Module, ValueId) -> Result<()>>;

struct VmState {
    regs: HashMap<ValueId, Rc<Tensor>>,
    refcounts: HashMap<ValueId, usize>,
    env: SymEnv,
    /// The VM re-executes shape functions per node visit (TVM's VM runs a
    /// shape function before each dynamic op; there is no cross-op
    /// symbolic sharing). Stashing the inputs lets each visit rebuild its
    /// environment the way the interpreted runtime does.
    inputs_snapshot: Vec<Tensor>,
    /// Concrete shapes the runtime tensor objects carry (each visit's
    /// shape function is seeded from these, then its results recorded).
    shape_cache: HashMap<SymId, i64>,
    metrics: RunMetrics,
}

impl VmState {
    fn reg(&self, v: ValueId) -> Result<&Tensor> {
        self.regs
            .get(&v)
            .map(|t| t.as_ref())
            .ok_or_else(|| anyhow::anyhow!("register %{v} empty"))
    }

    /// Shape resolution needs random access by value id; adapt the register
    /// map to the `Vals` view the env expects.
    fn vals_snapshot(&self, n: usize) -> Vec<Option<Rc<Tensor>>> {
        let mut v = vec![None; n];
        for (&k, t) in &self.regs {
            v[k] = Some(t.clone());
        }
        v
    }

    /// Per-visit shape-function execution: fresh environment, re-bound
    /// from the inputs and seeded with the concrete shapes carried on the
    /// runtime tensor objects, resolving this node's dims.
    fn run_shape_function(&mut self, m: &Module, id: ValueId) -> Result<Vec<usize>> {
        let mut env = SymEnv::new();
        env.bind_params(m, &self.inputs_snapshot)?;
        for (&k, &v) in &self.shape_cache {
            env.seed(k, v);
        }
        self.env = env;
        let snapshot = self.vals_snapshot(m.instrs.len());
        let dims = self.env.resolve_dims(m, &m.instrs[id].ty.dims, &snapshot[..]);
        self.shape_cache = self.env.resolved().clone();
        dims
    }

    fn release_operands(&mut self, m: &Module, id: ValueId, outputs: &[ValueId]) {
        for &o in &m.instrs[id].operands.clone() {
            if let Some(c) = self.refcounts.get_mut(&o) {
                *c = c.saturating_sub(1);
                if *c == 0 && !outputs.contains(&o) {
                    self.regs.remove(&o);
                }
            }
        }
    }
}

/// The VM: owns the same caches as the executor, interprets the graph.
pub struct Vm {
    pub cache: KernelCache,
    pub library: GemmLibrary,
}

impl Vm {
    pub fn new(
        device: Arc<crate::runtime::pjrt::Device>,
        policy: crate::codegen::BucketPolicy,
    ) -> Self {
        Vm { cache: KernelCache::new(device.clone(), policy), library: GemmLibrary::new(device) }
    }

    /// Interpret a module under a fusion plan.
    pub fn run(
        &mut self,
        m: &Module,
        plan: &FusionPlan,
        inputs: &[Tensor],
    ) -> Result<crate::runtime::executor::ExecOutput> {
        let t_start = Instant::now();
        let n = m.instrs.len();

        // --- per-run interpretation setup (Nimble builds its frame per
        // invocation: register file, refcounts, opcode decode) -------------
        let host = crate::fusion::host_shape_values(m);
        let mut opcodes: Vec<OpCode> = Vec::with_capacity(n);
        for (id, ins) in m.instrs.iter().enumerate() {
            opcodes.push(match &ins.op {
                Op::Param { .. } | Op::Const { .. } => OpCode::Nop,
                _ if host[id] => OpCode::HostEval,
                Op::Reshape | Op::DReshape => OpCode::Bitcast,
                Op::Dot => OpCode::Library,
                _ => match plan.membership[id] {
                    Some(g) if plan.groups[g].root == id => OpCode::FusedKernel,
                    Some(_) => OpCode::Nop,
                    None => OpCode::DeviceKernel,
                },
            });
        }
        let users = m.users();
        let mut state = VmState {
            regs: HashMap::new(),
            refcounts: users.iter().enumerate().map(|(i, u)| (i, u.len())).collect(),
            env: SymEnv::new(),
            inputs_snapshot: inputs.to_vec(),
            shape_cache: HashMap::new(),
            metrics: RunMetrics::default(),
        };
        state.env.bind_params(m, inputs)?;
        for (id, ins) in m.instrs.iter().enumerate() {
            match &ins.op {
                Op::Param { index } => {
                    state.regs.insert(id, Rc::new(inputs[*index].clone()));
                }
                Op::Const { lit, dims } => {
                    state.regs.insert(id, Rc::new(Tensor::from_literal(lit, dims)));
                }
                _ => {}
            }
        }

        let lib_flops0 = self.library.stats.flops;
        let cache0 = (self.cache.stats.misses, self.cache.stats.compile_time);

        // --- opcode handler table (dynamic dispatch per node visit) -------
        let handlers: HashMap<OpCode, Handler> = [
            (OpCode::Nop, Box::new(|_: &mut VmState, _: &Module, _: ValueId| Ok(())) as Handler),
            (
                OpCode::HostEval,
                Box::new(|st: &mut VmState, m: &Module, id: ValueId| {
                    let out_dims = st.run_shape_function(m, id)?;
                    let ins = &m.instrs[id];
                    let operands: Vec<&Tensor> =
                        ins.operands.iter().map(|&o| st.reg(o)).collect::<Result<_>>()?;
                    let t = eval_op(&ins.op, &operands, &out_dims, ins.ty.dtype)?;
                    st.metrics.host_ops += 1;
                    st.regs.insert(id, Rc::new(t));
                    Ok(())
                }) as Handler,
            ),
            (
                OpCode::Bitcast,
                Box::new(|st: &mut VmState, m: &Module, id: ValueId| {
                    let out_dims = st.run_shape_function(m, id)?;
                    let ins = &m.instrs[id];
                    let src = st.reg(ins.operands[0])?.clone();
                    st.metrics.bitcasts += 1;
                    st.regs.insert(id, Rc::new(src.with_dims(&out_dims)?));
                    Ok(())
                }) as Handler,
            ),
            (
                OpCode::DeviceKernel,
                Box::new(|st: &mut VmState, m: &Module, id: ValueId| {
                    let out_dims = if matches!(m.instrs[id].op, Op::Unique) {
                        vec![]
                    } else {
                        st.run_shape_function(m, id)?
                    };
                    let ins = &m.instrs[id];
                    let in_bytes: u64 = ins
                        .operands
                        .iter()
                        .map(|&o| st.reg(o).map(|t| t.byte_size() as u64))
                        .sum::<Result<u64>>()?;
                    st.metrics.mem_bytes += in_bytes;
                    let operands: Vec<&Tensor> =
                        ins.operands.iter().map(|&o| st.reg(o)).collect::<Result<_>>()?;
                    let tk = Instant::now();
                    let t = eval_op(&ins.op, &operands, &out_dims, ins.ty.dtype)?;
                    st.metrics.kernel_time += tk.elapsed();
                    st.metrics.mem_kernels += 1;
                    st.metrics.mem_bytes += t.byte_size() as u64;
                    if matches!(ins.op, Op::Unique) {
                        st.env.set_datadep(m, id, t.dims[0] as i64);
                        st.shape_cache = st.env.resolved().clone();
                    }
                    st.regs.insert(id, Rc::new(t));
                    Ok(())
                }) as Handler,
            ),
        ]
        .into_iter()
        .collect();

        // --- interpret: walk the graph node by node ------------------------
        for id in 0..n {
            match opcodes[id] {
                OpCode::Library => {
                    let ins = &m.instrs[id];
                    let a = state.reg(ins.operands[0])?.clone();
                    let b = state.reg(ins.operands[1])?.clone();
                    state.metrics.lib_bytes += (a.byte_size() + b.byte_size()) as u64;
                    let build0 = self.library.stats.build_time;
                    let exec0 = self.library.stats.exec_time;
                    let t = self.library.matmul(&a, &b)?;
                    state.metrics.lib_time += self.library.stats.exec_time - exec0;
                    state.metrics.compile_time += self.library.stats.build_time - build0;
                    state.metrics.lib_calls += 1;
                    state.metrics.lib_bytes += t.byte_size() as u64;
                    state.regs.insert(id, Rc::new(t));
                }
                OpCode::FusedKernel => {
                    // Per-visit recomputation of group metadata — the VM
                    // has no precompiled launch descriptors.
                    let gid = plan.membership[id].unwrap();
                    let g = &plan.groups[gid];
                    let sig = signature(m, g);
                    let syms = crate::codegen::hlo::group_syms(m, g);
                    // Per-visit shape function for the fused region.
                    let mut env = SymEnv::new();
                    env.bind_params(m, &state.inputs_snapshot)?;
                    for (&kk, &vv) in &state.shape_cache {
                        env.seed(kk, vv);
                    }
                    state.env = env;
                    let snapshot = state.vals_snapshot(n);
                    let mut actual = HashMap::with_capacity(syms.len());
                    for &s in &syms {
                        let v =
                            state.env.resolve_dim(m, crate::shape::Dim::Sym(s), &snapshot[..])?;
                        actual.insert(s, v);
                    }
                    state.shape_cache = state.env.resolved().clone();
                    let (kernel, _) = self.cache.get_or_compile(m, g, &sig, &actual)?;
                    let spec = &kernel.spec;
                    let externals = external_inputs(m, g);
                    // The VM clones per visit (interpreted register file).
                    let mut args_owned: Vec<Tensor> = Vec::new();
                    for (i, e) in externals.iter().enumerate() {
                        let src = state.reg(e.value)?.clone();
                        if src.dims == spec.input_dims[i] {
                            args_owned.push(src);
                        } else {
                            state.metrics.pad_copies += 1;
                            args_owned.push(pad_box(&src, &spec.input_dims[i], None)?);
                        }
                        // Bucket-shaped reads are real traffic (Nimble's
                        // fixed-shape-tuned kernels pay this on every
                        // off-tune shape, §4.5).
                        state.metrics.mem_bytes += args_owned.last().unwrap().byte_size() as u64;
                    }
                    for &li in &spec.extent_locals {
                        args_owned.push(Tensor::i32(&[], vec![actual[&syms[li]] as i32]));
                    }
                    let args: Vec<&Tensor> = args_owned.iter().collect();
                    let tk = Instant::now();
                    let out = kernel
                        .exe
                        .run(&args, &spec.out_dims, spec.out_dtype)
                        .with_context(|| format!("vm fused kernel {}", spec.name))?;
                    state.metrics.kernel_time += tk.elapsed();
                    state.metrics.mem_kernels += 1;
                    state.metrics.mem_bytes += out.byte_size() as u64;
                    let actual_out =
                        state.env.resolve_dims(m, &m.ty(g.root).dims, &snapshot[..])?;
                    let out =
                        if out.dims == actual_out { out } else { crop_box(&out, &actual_out)? };
                    state.regs.insert(id, Rc::new(out));
                }
                code => {
                    let h = handlers.get(&code).expect("handler registered");
                    h(&mut state, m, id)?;
                }
            }
            // Refcount-driven release per visit. Interior members of a
            // fused group consume their operands at the *root's* launch,
            // not at their own (skipped) visit.
            match plan.membership.get(id).copied().flatten() {
                Some(g) if plan.groups[g].root != id => {}
                Some(g) => {
                    for &member in &plan.groups[g].members {
                        state.release_operands(m, member, &m.outputs);
                    }
                }
                None => state.release_operands(m, id, &m.outputs),
            }
        }

        let outputs: Vec<Tensor> = m
            .outputs
            .iter()
            .map(|&o| {
                state
                    .regs
                    .get(&o)
                    .map(|t| t.as_ref().clone())
                    .ok_or_else(|| anyhow::anyhow!("output %{o} missing"))
            })
            .collect::<Result<_>>()?;

        let mut metrics = state.metrics;
        metrics.flops = self.library.stats.flops - lib_flops0;
        metrics.compile_events = self.cache.stats.misses - cache0.0;
        metrics.compile_time = self.cache.stats.compile_time - cache0.1;
        metrics.total_time = t_start.elapsed();
        Ok(crate::runtime::executor::ExecOutput { outputs, metrics })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::BucketPolicy;
    use crate::dhlo::{Builder, DType, UnKind};
    use crate::fusion::{plan, FusionOptions};
    use crate::runtime::pjrt::Device;
    use crate::runtime::reference::eval_module;
    use crate::shape::Dim;
    use crate::util::prng::Prng;

    fn nimble_plan(m: &Module) -> FusionPlan {
        plan(m, &FusionOptions { use_constraints: false, ..Default::default() })
    }

    #[test]
    fn vm_matches_reference_numerics() {
        let mut b = Builder::new("vmtest");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(4)]);
        let sm = b.softmax_last(x).unwrap();
        let t = b.unary(UnKind::Tanh, sm);
        let m = b.finish(vec![t]);
        let p = nimble_plan(&m);
        let dev = Arc::new(Device::cpu().unwrap());
        let mut vm = Vm::new(dev, BucketPolicy::NextPow2);
        let mut rng = Prng::new(3);
        for rows in [2usize, 5, 9] {
            let input = Tensor::f32(&[rows, 4], rng.fill_f32(rows * 4, 1.5));
            let got = vm.run(&m, &p, &[input.clone()]).unwrap();
            let want = eval_module(&m, &[input]).unwrap();
            assert!(got.outputs[0].allclose(&want.outputs[0], 1e-5, 1e-5).unwrap());
        }
    }

    #[test]
    fn vm_and_mlp_library_path() {
        let mut b = Builder::new("vmlib");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s, Dim::Fixed(8)]);
        let w = b.param(DType::F32, vec![Dim::Fixed(8), Dim::Fixed(8)]);
        let h = b.dot(x, w).unwrap();
        let r = b.unary(UnKind::Relu, h);
        let m = b.finish(vec![r]);
        let p = nimble_plan(&m);
        let dev = Arc::new(Device::cpu().unwrap());
        let mut vm = Vm::new(dev, BucketPolicy::NextPow2);
        let x_t = Tensor::f32(&[3, 8], vec![0.25; 24]);
        let w_t = Tensor::f32(&[8, 8], vec![0.125; 64]);
        let got = vm.run(&m, &p, &[x_t.clone(), w_t.clone()]).unwrap();
        let want = eval_module(&m, &[x_t, w_t]).unwrap();
        assert!(got.outputs[0].allclose(&want.outputs[0], 1e-5, 1e-5).unwrap());
        assert_eq!(got.metrics.lib_calls, 1);
    }

    #[test]
    fn vm_buffers_released_by_refcount() {
        let mut b = Builder::new("rc");
        let s = b.dyn_dim("n", 0, 0);
        let x = b.param(DType::F32, vec![s]);
        let t = b.unary(UnKind::Tanh, x);
        let e = b.unary(UnKind::Exp, t);
        let m = b.finish(vec![e]);
        // Disable fusion so intermediates materialize.
        let p = plan(&m, &FusionOptions { enabled: false, ..Default::default() });
        let dev = Arc::new(Device::cpu().unwrap());
        let mut vm = Vm::new(dev, BucketPolicy::NextPow2);
        let got = vm.run(&m, &p, &[Tensor::f32(&[4], vec![0.1; 4])]).unwrap();
        assert_eq!(got.outputs[0].dims, vec![4]);
        assert_eq!(got.metrics.mem_kernels, 2, "two singleton kernels without fusion");
    }
}
