//! Minimal argument parser (no external crates available offline) and the
//! `disc` CLI subcommands.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// Parsed arguments: a subcommand, `--key value` flags, and positionals.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: HashMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            out.command = cmd.clone();
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --flag=value or --flag value or boolean --flag
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(key.to_string(), it.next().unwrap().clone());
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} wants an integer")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

pub fn parse_mode(s: &str) -> Result<crate::compiler::Mode> {
    use crate::compiler::Mode;
    Ok(match s {
        "eager" => Mode::Eager,
        "vm" | "nimble" => Mode::VmNimble,
        "disc" | "dynamic" => Mode::Disc,
        "static" | "xla" => Mode::Static,
        "auto" => Mode::Auto,
        other => bail!("unknown mode '{other}' (eager|vm|disc|static|auto)"),
    })
}

pub const USAGE: &str = "\
disc — dynamic shape compiler (DISC reproduction)

USAGE:
  disc run      --workload <name> [--mode disc] [--requests 50] [--seed 1]
                [--open-rate <rps>] [--workers N] [--burst B] [--warm]
                [--batch K] [--batch-window-us U] [--no-memplan]
                [--deadline-ms D] [--faults <spec>]
                [--rebucket-interval MS] [--max-buckets K]
                (--workers >1 serves the open-loop stream from N executor
                 threads sharing one kernel/weight store; --burst switches
                 to on/off arrivals; --warm precompiles neighbor buckets in
                 the background; --no-memplan disables the compile-time
                 symbolic memory planner (replays fall back to per-buffer
                 arena blocks); --batch >1 coalesces queued same-group
                 requests into one stacked launch, waiting up to U us for
                 stragglers once the queue runs dry; --deadline-ms sheds
                 requests still queued D ms after arrival; --faults arms a
                 fault-injection schedule for the worker-panic seam, e.g.
                 \"seed=7,panic=100:2\" — device seams read DISC_FAULTS,
                 see docs/runtime.md; --rebucket-interval >0 runs a
                 background loop every MS ms that re-derives bucket
                 boundaries (at most --max-buckets cuts per symbol) from
                 the observed extent histogram, pre-compiles the new
                 family off the hot path, and hot-swaps the policy epoch
                 with zero compile stall — see docs/runtime.md
                 §Bucketing & re-bucketing)
  disc run mix  [--tenants name:workload[:slo[:weight[:floor-mb]]],...]
                [--requests N] [--rate R] [--workers N] [--batch K]
                [--deadline-ms D] [--seed S] [--faults <spec>]
                [--fault-tenant <name>] [--breaker T] [--probe-after P]
                [--quarantine reference|shed] [--weight-budget-mb M]
                [--rebucket-interval MS] [--max-buckets K]
                (multi-tenant serving: each tenant gets its own bounded
                 queue, SLO class (latency = zero straggler window,
                 throughput = wide), weighted-fair share of the worker
                 pool, and a residency floor in the shared weight cache;
                 consecutive dispatch failures trip a per-tenant circuit
                 breaker — quarantined requests are answered by the host
                 reference evaluator (or shed) until a probe re-admits.
                 --fault-tenant arms --faults worker-panic injection
                 inside that tenant's dispatches only)
  disc inspect  --workload <name> | --file <graph.json>
  disc import   --file <graph.json> [--mode disc] [--requests N]
  disc list     (show available workloads)

  The 'decode' workload serves autoregressive decode loops instead of
  one-shot requests: --requests jobs of --prompt-len prompt tokens plus
  --gen-steps generated tokens each, scheduled with iteration-level
  continuous batching (--batch slots, --stagger boundaries between
  arrivals; --deadline-ms and --faults shed/panic as above). Each job's
  KV cache lives in the executor arena as a bucket-sized slab, so
  consecutive steps replay one launch-plan family until rollover.

Workloads: asr_tf asr_pt seq2seq tts bert ad_ranking transformer decode
Modes:     eager (TF/PyTorch baseline), vm (Nimble-like), disc, static (XLA-like), auto
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&sv(&["run", "--workload", "bert", "--requests=10", "x", "--verbose"]))
            .unwrap();
        assert_eq!(a.command, "run");
        assert_eq!(a.get("workload"), Some("bert"));
        assert_eq!(a.get_usize("requests", 0).unwrap(), 10);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["x"]);
    }

    #[test]
    fn defaults_and_errors() {
        let a = Args::parse(&sv(&["run"])).unwrap();
        assert_eq!(a.get_usize("requests", 7).unwrap(), 7);
        let b = Args::parse(&sv(&["run", "--requests", "abc"])).unwrap();
        assert!(b.get_usize("requests", 0).is_err());
    }

    #[test]
    fn mode_parsing() {
        assert!(parse_mode("disc").is_ok());
        assert!(parse_mode("nimble").is_ok());
        assert!(parse_mode("wat").is_err());
    }
}
