"""Pallas kernels vs pure-jnp oracles: the L1 correctness signal.

Hypothesis sweeps shapes (and the valid-extent scalar for the masked
kernels); every kernel must match its oracle to float32 tolerances.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

RNG = np.random.default_rng(0)


def rand(shape, scale=1.0):
    return jnp.asarray(RNG.normal(0.0, scale, size=shape).astype(np.float32))


dims_rows = st.integers(min_value=1, max_value=96)
dims_hidden = st.sampled_from([8, 16, 64, 128])


@given(rows=dims_rows, hidden=dims_hidden)
def test_bias_gelu_matches_ref(rows, hidden):
    x = rand((rows, hidden))
    b = rand((hidden,), 0.5)
    got = fused.bias_gelu(x, b)
    want = ref.bias_gelu(x, b)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(rows=dims_rows, hidden=dims_hidden)
def test_layernorm_matches_ref(rows, hidden):
    x = rand((rows, hidden))
    g = rand((hidden,), 0.5) + 1.0
    b = rand((hidden,), 0.5)
    got = fused.layernorm(x, g, b)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(rows=st.integers(1, 32), bucket=st.sampled_from([16, 32, 64]), data=st.data())
def test_masked_softmax_matches_ref(rows, bucket, data):
    n = data.draw(st.integers(min_value=1, max_value=bucket))
    x = rand((rows, bucket), 2.0)
    got = fused.masked_softmax(x, jnp.int32(n))
    want = ref.masked_softmax(x, jnp.int32(n))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    # Valid lanes sum to one; masked lanes are exactly zero.
    np.testing.assert_allclose(np.asarray(got)[:, :n].sum(axis=1), 1.0, rtol=1e-5)
    assert (np.asarray(got)[:, n:] == 0.0).all()


@given(rows=st.integers(1, 64), hidden=dims_hidden)
def test_residual_layernorm_matches_ref(rows, hidden):
    x = rand((rows, hidden))
    r = rand((rows, hidden))
    g = rand((hidden,), 0.5) + 1.0
    b = rand((hidden,), 0.5)
    got = fused.residual_layernorm(x, r, g, b)
    want = ref.residual_layernorm(x, r, g, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_masked_softmax_ignores_garbage_tail():
    """The shape-adaptive contract: tail contents must not affect results."""
    x = rand((4, 32), 1.0)
    poisoned = x.at[:, 20:].set(1e30)
    n = jnp.int32(20)
    clean = fused.masked_softmax(x, n)
    dirty = fused.masked_softmax(poisoned, n)
    np.testing.assert_allclose(np.asarray(clean)[:, :20], np.asarray(dirty)[:, :20], rtol=1e-6)


@pytest.mark.parametrize("block_rows", [16, 64, 128])
def test_bias_gelu_block_shapes_equivalent(block_rows):
    """Different BlockSpec tilings must not change numerics (the L1 perf
    knob is layout-only)."""
    x = rand((128, 64))
    b = rand((64,))
    got = fused.bias_gelu(x, b, block_rows=block_rows)
    want = ref.bias_gelu(x, b)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_kernels_lower_to_hlo_text():
    """Every kernel must survive the AOT path (StableHLO → HLO text)."""
    from compile.aot import to_hlo_text

    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64,), jnp.float32)
    lowered = jax.jit(lambda a, c: (fused.bias_gelu(a, c),)).lower(x, b)
    text = to_hlo_text(lowered)
    assert "HloModule" in text and "ENTRY" in text
