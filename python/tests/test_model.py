"""L2 model checks: the Pallas-backed encoder block vs its pure-jnp oracle,
bucket masking invariants, and AOT lowering of every bucket variant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def weights():
    return model.BlockWeights.init(jax.random.PRNGKey(0))


def rand_x(bucket, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(0, 1, size=(bucket, model.HIDDEN)).astype(np.float32))


@pytest.mark.parametrize("bucket,n", [(32, 32), (32, 17), (64, 40), (128, 100)])
def test_block_matches_reference(weights, bucket, n):
    x = rand_x(bucket)
    got = model.encoder_block(x, jnp.int32(n), weights)
    want = model.reference_block(x, jnp.int32(n), weights)
    np.testing.assert_allclose(
        np.asarray(got)[:n], np.asarray(want)[:n], rtol=2e-5, atol=2e-5
    )


def test_padding_rows_do_not_affect_valid_rows(weights):
    """Box-validity: garbage in rows >= n must not leak into rows < n."""
    bucket, n = 64, 23
    x = rand_x(bucket)
    poisoned = x.at[n:].set(1e6)
    a = model.encoder_block(x, jnp.int32(n), weights)
    b = model.encoder_block(poisoned, jnp.int32(n), weights)
    np.testing.assert_allclose(np.asarray(a)[:n], np.asarray(b)[:n], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bucket", aot.BUCKETS)
def test_bucket_variants_lower(bucket):
    text = aot.lower_model_bucket(bucket)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # The extent scalar parameter must survive lowering.
    assert "s32[]" in text


def test_gemm_artifacts_lower():
    text = aot.lower_gemm(32, 64, 64)
    assert "dot" in text


def test_block_output_shape(weights):
    x = rand_x(32)
    out = model.encoder_block(x, jnp.int32(32), weights)
    assert out.shape == (32, model.HIDDEN)
    assert out.dtype == jnp.float32
