"""Layer-2 JAX model: a transformer encoder block over a *bucket-shaped*
sequence, calling the Layer-1 Pallas kernels.

This is the AOT half of the reproduction's §4.3/§4.5 story: the block is
lowered once per sequence bucket (with the actual length arriving as a
scalar ``n``), and the Rust runtime's host-side selection logic picks the
variant per request — DISC's shape-adaptive fusion configuration realized
as AOT artifacts. Padding rows beyond ``n`` are garbage-tolerant: every
reduction over the dynamic axis is masked (attention via
``masked_softmax``) and the caller crops the output box.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import fused

HIDDEN = 64
HEADS = 4
HEAD_DIM = HIDDEN // HEADS
FFN = 128


@dataclass
class BlockWeights:
    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    ln1_g: jax.Array
    ln1_b: jax.Array
    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array
    ln2_g: jax.Array
    ln2_b: jax.Array

    @staticmethod
    def init(key, hidden: int = HIDDEN, ffn: int = FFN) -> "BlockWeights":
        ks = jax.random.split(key, 12)
        s = 1.0 / jnp.sqrt(hidden)
        return BlockWeights(
            wq=jax.random.normal(ks[0], (hidden, hidden), jnp.float32) * s,
            wk=jax.random.normal(ks[1], (hidden, hidden), jnp.float32) * s,
            wv=jax.random.normal(ks[2], (hidden, hidden), jnp.float32) * s,
            wo=jax.random.normal(ks[3], (hidden, hidden), jnp.float32) * s,
            ln1_g=jnp.ones((hidden,), jnp.float32),
            ln1_b=jnp.zeros((hidden,), jnp.float32),
            w1=jax.random.normal(ks[4], (hidden, ffn), jnp.float32) * s,
            b1=jnp.zeros((ffn,), jnp.float32),
            w2=jax.random.normal(ks[5], (ffn, hidden), jnp.float32) * (1.0 / jnp.sqrt(ffn)),
            b2=jnp.zeros((hidden,), jnp.float32),
            ln2_g=jnp.ones((hidden,), jnp.float32),
            ln2_b=jnp.zeros((hidden,), jnp.float32),
        )

    def flat(self):
        return [
            self.wq, self.wk, self.wv, self.wo,
            self.ln1_g, self.ln1_b,
            self.w1, self.b1, self.w2, self.b2,
            self.ln2_g, self.ln2_b,
        ]


def encoder_block(x, n, w: BlockWeights):
    """One encoder block over ``x: [bucket, HIDDEN]`` with ``n`` valid rows.

    Matmuls use the MXU path (plain jnp.dot lowers to XLA dot); the
    memory-intensive epilogues go through the Pallas kernels.
    """
    bucket = x.shape[0]

    q = x @ w.wq
    k = x @ w.wk
    v = x @ w.wv

    def heads(t):  # [bucket, H] -> [HEADS, bucket, HEAD_DIM]
        return t.reshape(bucket, HEADS, HEAD_DIM).transpose(1, 0, 2)

    qh, kh, vh = heads(q), heads(k), heads(v)
    scores = jnp.einsum("hsd,htd->hst", qh, kh) / jnp.sqrt(float(HEAD_DIM))
    # Masked softmax over the dynamic axis, head by head through the fused
    # kernel (rows = HEADS * bucket after flattening).
    flat_scores = scores.reshape(HEADS * bucket, bucket)
    attn = fused.masked_softmax(flat_scores, n).reshape(HEADS, bucket, bucket)
    ctx = jnp.einsum("hst,htd->hsd", attn, vh)
    merged = ctx.transpose(1, 0, 2).reshape(bucket, HIDDEN)
    proj = merged @ w.wo

    h1 = fused.residual_layernorm(proj, x, w.ln1_g, w.ln1_b)

    f = fused.bias_gelu(h1 @ w.w1, w.b1)
    f2 = (f @ w.w2) + w.b2[None, :]
    return fused.residual_layernorm(f2, h1, w.ln2_g, w.ln2_b)


def reference_block(x, n, w: BlockWeights):
    """Pure-jnp oracle of :func:`encoder_block` (no Pallas)."""
    from .kernels import ref

    bucket = x.shape[0]
    q, k, v = x @ w.wq, x @ w.wk, x @ w.wv

    def heads(t):
        return t.reshape(bucket, HEADS, HEAD_DIM).transpose(1, 0, 2)

    qh, kh, vh = heads(q), heads(k), heads(v)
    scores = jnp.einsum("hsd,htd->hst", qh, kh) / jnp.sqrt(float(HEAD_DIM))
    attn = ref.masked_softmax(scores.reshape(HEADS * bucket, bucket), n)
    attn = attn.reshape(HEADS, bucket, bucket)
    ctx = jnp.einsum("hst,htd->hsd", attn, vh)
    merged = ctx.transpose(1, 0, 2).reshape(bucket, HIDDEN)
    proj = merged @ w.wo
    h1 = ref.residual_layernorm(proj, x, w.ln1_g, w.ln1_b)
    f = ref.bias_gelu(h1 @ w.w1, w.b1)
    f2 = (f @ w.w2) + w.b2[None, :]
    return ref.residual_layernorm(f2, h1, w.ln2_g, w.ln2_b)


def block_fn_for_bucket(bucket: int):
    """A jit-able function of (x, n, *flat_weights) for AOT lowering at a
    fixed bucket shape. Returns a 1-tuple (the Rust loader unwraps it)."""

    def fn(x, n, *flat):
        w = BlockWeights(*flat)
        return (encoder_block(x, n, w),)

    return fn
