"""AOT lowering: JAX/Pallas → HLO text artifacts the Rust runtime loads.

Interchange is HLO *text* (not serialized HloModuleProto): jax ≥ 0.5 emits
protos with 64-bit instruction ids which the bundled xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifacts produced (under ``artifacts/``):
  * ``model_s{bucket}.hlo.txt`` — the L2 encoder block at each sequence
    bucket (the §4.3 shape-adaptive variant family the Rust serving example
    selects from at runtime);
  * ``gemm_{m}x{k}x{n}.hlo.txt`` — pre-generated library entries (§4.5)
    for the transformer workload's GEMM shapes;
  * ``manifest.json`` — machine-readable index (shapes, parameter order)
    the Rust `runtime::artifacts` loader consumes.

Python runs ONCE at build time (`make artifacts`); the request path is
pure Rust.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod

BUCKETS = [32, 64, 128]
GEMM_SHAPES = [
    # (m_bucket, k, n): transformer workload projections and FFN.
    (32, 64, 64),
    (64, 64, 64),
    (128, 64, 64),
    (64, 64, 128),
    (64, 128, 64),
]


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    """StableHLO → XlaComputation → HLO text (the only interchange the
    bundled XLA parses; `.serialize()` protos are rejected)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def lower_model_bucket(bucket: int) -> str:
    fn = model_mod.block_fn_for_bucket(bucket)
    x = jax.ShapeDtypeStruct((bucket, model_mod.HIDDEN), jnp.float32)
    n = jax.ShapeDtypeStruct((), jnp.int32)
    w = model_mod.BlockWeights.init(jax.random.PRNGKey(0))
    flat_specs = [jax.ShapeDtypeStruct(t.shape, t.dtype) for t in w.flat()]
    lowered = jax.jit(fn).lower(x, n, *flat_specs)
    return to_hlo_text(lowered)


def lower_gemm(m: int, k: int, n: int) -> str:
    # Bare (non-tuple) root: the Rust GemmLibrary expects an array output.
    def fn(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((m, k), jnp.float32)
    b = jax.ShapeDtypeStruct((k, n), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(a, b), return_tuple=False)


def weight_arrays():
    """The deterministic weights baked into the artifacts' manifest so the
    Rust side feeds the same values the pytest oracle used."""
    w = model_mod.BlockWeights.init(jax.random.PRNGKey(0))
    return w.flat()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"models": [], "gemms": [], "hidden": model_mod.HIDDEN}

    for bucket in BUCKETS:
        path = f"model_s{bucket}.hlo.txt"
        text = lower_model_bucket(bucket)
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["models"].append(
            {
                "path": path,
                "bucket": bucket,
                "hidden": model_mod.HIDDEN,
                "params": "x, n, wq, wk, wv, wo, ln1_g, ln1_b, w1, b1, w2, b2, ln2_g, ln2_b",
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    for (m, k, n) in GEMM_SHAPES:
        path = f"gemm_{m}x{k}x{n}.hlo.txt"
        text = lower_gemm(m, k, n)
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        manifest["gemms"].append({"path": path, "m": m, "k": k, "n": n})
        print(f"wrote {path} ({len(text)} chars)")

    # Weights, flattened row-major, so the Rust driver can reproduce the
    # exact pytest numerics end-to-end.
    weights_path = os.path.join(args.out_dir, "weights.json")
    flat = weight_arrays()
    names = [
        "wq", "wk", "wv", "wo", "ln1_g", "ln1_b",
        "w1", "b1", "w2", "b2", "ln2_g", "ln2_b",
    ]
    weights = {
        name: {"dims": list(t.shape), "data": [float(v) for v in t.reshape(-1)]}
        for name, t in zip(names, flat)
    }
    with open(weights_path, "w") as f:
        json.dump(weights, f)
    print(f"wrote weights.json ({os.path.getsize(weights_path)} bytes)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
