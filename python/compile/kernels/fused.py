"""Layer-1 Pallas kernels: DISC's fused-kernel templates, adapted for TPU.

The paper's CUDA fusion templates (classic loop fusion, input fusion with a
reduce root, §4.3) become Pallas kernels whose iteration space is expressed
with BlockSpecs (HBM→VMEM tiling in place of thread-block shaping). Dynamic
shapes are handled exactly like the Rust codegen handles them — and exactly
like the paper's "shape-adaptive fusion configuration": each kernel is
compiled at a *bucket* shape, takes the actual extent as a scalar operand,
and masks the padded tail in-kernel where a reduction would otherwise read
garbage. Host-side selection logic (the Rust runtime) picks the right
bucket variant per incoming shape.

All kernels use ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so lowering goes through the interpreter to plain HLO
(numerically identical; see DESIGN.md §Hardware-Adaptation for the real-TPU
performance estimate).

Block-shape conventions (TPU VPU lanes are 8×128):
  * the minor (feature/sequence) axis is padded to a multiple of 128 by the
    bucket choice where possible;
  * full rows stay resident in VMEM across each fused chain, which is what
    removes the HBM round-trips the paper counts.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU-PJRT compatibility; flip off on a real TPU.


def erf_approx(x):
    """Abramowitz–Stegun 7.1.26 erf (|err| < 1.5e-7).

    Used instead of ``jax.lax.erf`` because the bundled xla_extension 0.5.1
    HLO-text parser predates the dedicated `erf` opcode; this expansion
    lowers to mul/add/exp only, and matches the Rust reference interpreter
    and HLO emitter bit-for-bit in formula.
    """
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    poly = ((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t + 0.254829592
    y = 1.0 - poly * t * jnp.exp(-ax * ax)
    return sign * y


# ---------------------------------------------------------------------------
# bias + gelu (classic loop fusion: matmul epilogue chain)
# ---------------------------------------------------------------------------


def _bias_gelu_kernel(x_ref, b_ref, o_ref):
    x = x_ref[...]
    b = b_ref[...]
    h = x + b[None, :]
    # erf-based gelu, matching the Rust reference and HLO emitter.
    o_ref[...] = 0.5 * h * (1.0 + erf_approx(h / jnp.sqrt(2.0).astype(h.dtype)))


@functools.partial(jax.jit, static_argnames=("block_rows",))
def bias_gelu(x, b, block_rows: int = 128):
    """Fused ``gelu(x + b)`` over ``x: [rows, hidden]``, ``b: [hidden]``.

    Elementwise-only fusion: no masking needed — padded-tail garbage is
    never read back (the caller crops), mirroring the Rust executor's
    box-validity invariant.
    """
    rows, hidden = x.shape
    grid = (max(1, rows // min(block_rows, rows)),)
    rb = rows // grid[0]
    return pl.pallas_call(
        _bias_gelu_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, hidden), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, hidden), lambda i: (i, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, hidden), lambda i: (i, 0)),
        interpret=INTERPRET,
    )(x, b)


# ---------------------------------------------------------------------------
# layernorm (input fusion rooted at the mean/variance reduces)
# ---------------------------------------------------------------------------


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    centered = x - mean
    var = jnp.mean(centered * centered, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = centered * inv * g_ref[...][None, :] + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def layernorm(x, gamma, beta, eps: float = 1e-5, block_rows: int = 128):
    """Row layernorm over ``x: [rows, hidden]`` (hidden is static, so the
    reduction needs no runtime mask)."""
    rows, hidden = x.shape
    grid = (max(1, rows // min(block_rows, rows)),)
    rb = rows // grid[0]
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((rows, hidden), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, hidden), lambda i: (i, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, hidden), lambda i: (i, 0)),
        interpret=INTERPRET,
    )(x, gamma, beta)


# ---------------------------------------------------------------------------
# masked softmax (the shape-adaptive kernel: dynamic axis in a bucket)
# ---------------------------------------------------------------------------


def _masked_softmax_kernel(x_ref, n_ref, o_ref):
    x = x_ref[...]
    n = n_ref[0]
    cols = x.shape[-1]
    lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, len(x.shape) - 1)
    valid = lane < n
    neg_inf = jnp.finfo(x.dtype).min
    masked = jnp.where(valid, x, neg_inf)
    mx = jnp.max(masked, axis=-1, keepdims=True)
    e = jnp.exp(masked - mx)
    e = jnp.where(valid, e, 0.0)
    s = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = e / s
    del cols


@jax.jit
def masked_softmax(x, n):
    """Softmax over the last axis of a *bucket-shaped* ``x: [rows, bucket]``
    where only the first ``n`` lanes are valid (attention scores over a
    dynamic sequence length).

    This is the §4.3 shape-adaptive template: one compiled artifact per
    bucket, the actual extent arrives at runtime as ``n``, and the masked
    tail produces exact zeros so downstream matmuls ignore the padding.
    """
    rows, bucket = x.shape
    return pl.pallas_call(
        _masked_softmax_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, bucket), x.dtype),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((rows, bucket), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, bucket), lambda i: (0, 0)),
        interpret=INTERPRET,
    )(x, n.reshape((1,)))


# ---------------------------------------------------------------------------
# residual add + layernorm (the transformer's hottest fused epilogue)
# ---------------------------------------------------------------------------


def _residual_layernorm_kernel(x_ref, r_ref, g_ref, b_ref, o_ref, *, eps):
    h = x_ref[...] + r_ref[...]
    mean = jnp.mean(h, axis=-1, keepdims=True)
    centered = h - mean
    var = jnp.mean(centered * centered, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = centered * inv * g_ref[...][None, :] + b_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("eps",))
def residual_layernorm(x, resid, gamma, beta, eps: float = 1e-5):
    """Fused ``layernorm(x + resid)`` — loop fusion feeding an input fusion,
    one VMEM-resident pass instead of two kernels + an HBM round trip."""
    rows, hidden = x.shape
    return pl.pallas_call(
        functools.partial(_residual_layernorm_kernel, eps=eps),
        out_shape=jax.ShapeDtypeStruct((rows, hidden), x.dtype),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((rows, hidden), lambda i: (0, 0)),
            pl.BlockSpec((rows, hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rows, hidden), lambda i: (0, 0)),
        interpret=INTERPRET,
    )(x, resid, gamma, beta)
