"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
contract: pytest asserts allclose between each kernel and its oracle over
hypothesis-driven shape/dtype sweeps)."""

import jax
import jax.numpy as jnp

from .fused import erf_approx


def bias_gelu(x, b):
    h = x + b[None, :]
    return 0.5 * h * (1.0 + erf_approx(h / jnp.sqrt(2.0).astype(h.dtype)))


def layernorm(x, gamma, beta, eps: float = 1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    centered = x - mean
    var = jnp.mean(centered * centered, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return centered * inv * gamma[None, :] + beta[None, :]


def masked_softmax(x, n):
    """Softmax over the first ``n`` lanes of the last axis; zeros beyond."""
    lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
    valid = lane < n
    masked = jnp.where(valid, x, jnp.finfo(x.dtype).min)
    mx = jnp.max(masked, axis=-1, keepdims=True)
    e = jnp.exp(masked - mx)
    e = jnp.where(valid, e, 0.0)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def residual_layernorm(x, resid, gamma, beta, eps: float = 1e-5):
    return layernorm(x + resid, gamma, beta, eps)
